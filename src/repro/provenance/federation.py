"""FederatedSession — plan splitting and mask stitching across a catalog.

The federated twin of :class:`~repro.provenance.session.QuerySession`, with
the same surface (``run`` / ``run_many`` / ``explain`` / ``stats``) over a
:class:`~repro.provenance.catalog.ProvCatalog` instead of one index.

Execution model — record-level plans are *linear* in the probe mask (record
propagation distributes over union), so a cross-index query factors into
per-member segments joined by link stitches:

1. **Route.**  Member-level reachability over the link graph (a DAG; cycles
   raise).  Only members on some ``source``-member → ``target``-member path
   participate.
2. **Propagate.**  Per member, in link-topological order: every entry mask
   (the original probe, or masks stitched in over incoming links) advances
   to every needed exit dataset through ONE record plan on the owning
   member's own cost-model-driven ``QuerySession`` — the member's
   ``ComposedIndex`` stays private, keeps its append-survival semantics,
   and its walk-vs-compose routing applies per segment.  Exits reached from
   several entries UNION (exactly what a merged index's walk would do), so
   diamonds that span the boundary — two links carrying two branches of one
   upstream source into one downstream join — answer exactly.
3. **Stitch.**  ``(B, n)`` mask stacks cross each link through its row
   alignment (:meth:`~repro.provenance.catalog.Link.stitch_down` /
   ``stitch_up``), then keep propagating.

``run_many`` fuses plans sharing a fuse key exactly like ``QuerySession``
(the probe stacks concatenate), so a batch of cross-index traces still
packs into ONE pass per member segment.

**Cross-boundary composed relations.**  Segment-at-a-time execution pays
one composed-relation probe per member.  For a HOT route the federation
additionally memoizes the fully STITCHED relation — each member's composed
relation (read through :meth:`relation_csr`, the same probe capability a
``BoundaryHandle`` grants) chained through the link alignment matrices and
unioned over parallel link paths — so a sustained cross-index workload
probes ONE relation, exactly like a merged single index would.  The cache
lives in a CATALOG-owned :class:`_CrossStore` (per-member ``ComposedIndex``
caches stay private; every session over the same catalog — the serving
tier's, an auditor's, a bench's — shares the stitched relations and the
accumulated route demand, so a route one session made hot stays hot for
all), is bounded by ``cross_budget_bytes`` (LRU), and invalidates when the
link set changes (member indexes are append-only, so member-side writes
never invalidate an existing route).

*When* a route composes is decided by the cost model
(:func:`repro.core.costmodel.cross_route_choose`): per-segment relation
statistics (each member's :meth:`relation_stats` capability read — counts,
never tensors) price segment-at-a-time execution against the stitched
relation's one-time composition amortized over the route's cumulative
probe demand, with the store's byte budget as a retention guard.  Passing
an explicit ``cross_min_demand=`` integer keeps the legacy fixed demand
floor for that session instead.

Plan-kind support: every batched kind routes across members.  ``record``
(fwd/bwd) and the co-queries (explicit ``via`` for Q10) split into
per-member record segments as above.  ``cells`` / ``how`` plans — whose
attribute bitplanes and hop traces live on each index's per-op walk — run
as per-member TERM walks instead (:meth:`QuerySession.run_attr_terms` /
``run_record_terms``: every boundary entry of a member seeds ONE pass, so
hop traces match a merged index's single walk): row masks cross each link
through its row alignment and attribute masks re-align BY COLUMN NAME
between the two boundary datasets (columns absent on the far side drop).
Each crossing adds a synthetic ``category="link"`` hop to how-traces.
``transformations`` is single-ref and delegates.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.compose import HAVE_SCIPY
from repro.core.costmodel import RelStats, cross_route_choose
from repro.core.query import Hop, _cells_batch
from repro.core.provtensor import pack_bitplane, unpack_bitplane
from repro.provenance.catalog import (
    CapabilityError,
    FederationError,
    Link,
    ProvCatalog,
    split_ref,
)
from repro.provenance.plan import QueryPlan
from repro.provenance.session import run_many_fused

__all__ = ["FederatedSession"]

DEFAULT_CROSS_BUDGET_BYTES = 64 << 20


class _CrossStore:
    """Catalog-owned stitched cross-relation cache + route demand.

    One store per :class:`ProvCatalog`, shared by every
    :class:`FederatedSession` over it — the serving tier's sessions, ad-hoc
    audit sessions, and benches all see the same hot routes (the carried
    PR 4 follow-up: stitched relations shared ACROSS sessions).  All
    mutation happens inside session calls, which callers already serialize
    per catalog (the serving tier's single executor, or single-threaded
    use)."""

    def __init__(self, budget_bytes: int = DEFAULT_CROSS_BUDGET_BYTES) -> None:
        self.budget_bytes = int(budget_bytes)
        # route key (start, end, mode) -> (relT csr, crossed-link signature)
        self.entries: "OrderedDict[Tuple[str, str, str], tuple]" = OrderedDict()
        self.nbytes = 0
        self.failed: set = set()        # routes not worth/able to compose
        self.demand: Dict[Tuple[str, str, str], int] = {}
        self.links_version: Optional[int] = None

    @staticmethod
    def rel_nbytes(rel) -> int:
        return int(rel.data.nbytes + rel.indices.nbytes + rel.indptr.nbytes)

    def get(self, key):
        entry = self.entries.get(key)
        if entry is None:
            return None
        self.entries.move_to_end(key)
        return entry

    def put(self, key, rel, signature: frozenset) -> bool:
        nbytes = self.rel_nbytes(rel)
        if nbytes > self.budget_bytes:
            return False                # larger than the budget: keep segments
        old = self.entries.pop(key, None)
        if old is not None:
            self.nbytes -= self.rel_nbytes(old[0])
        self.entries[key] = (rel, signature)
        self.nbytes += nbytes
        while self.nbytes > self.budget_bytes and len(self.entries) > 1:
            _, (evicted, _) = self.entries.popitem(last=False)
            self.nbytes -= self.rel_nbytes(evicted)
        return True

    def drop(self, key) -> None:
        entry = self.entries.pop(key, None)
        if entry is not None:
            self.nbytes -= self.rel_nbytes(entry[0])


@dataclasses.dataclass
class _Segment:
    """One intra-member record hop of a federated route (explain unit)."""

    member: str
    source: str             # unqualified, within the member
    target: str
    direction: str          # "fwd" | "bwd"


# ---------------------------------------------------------------------------
# Traversal semirings: ONE route walk (_traverse), three value domains.
# Keeping mask propagation, relation composition and dry routing on the
# same traversal is what guarantees the hot (stitched-relation) path can
# never answer differently from the cold (segment) path.
# ---------------------------------------------------------------------------
class _DryOps:
    """Reachability only: no member work, values are the literal True."""

    def extend(self, member, value, src, dst, direction):
        return True

    def union(self, a, b):
        return True

    def settle(self, acc):
        return acc

    def stitch(self, link, value, reverse, n_up, n_down):
        return True


class _MaskOps:
    """(B, n) boolean mask stacks through each member's QuerySession."""

    def __init__(self, session: "FederatedSession") -> None:
        self.session = session

    def extend(self, member, value, src, dst, direction):
        self.session.counters["segments"] += 1
        return member.run_masks(QueryPlan(
            kind="record", source=src, target=dst, direction=direction,
            rows=value, batched=True))

    def union(self, a, b):
        return a | b

    def settle(self, acc):
        return acc

    def stitch(self, link, value, reverse, n_up, n_down):
        return link.stitch_up(value, n_up) if reverse \
            else link.stitch_down(value, n_down)


class _RelOps:
    """(n_start, n_ds) scipy-CSR relations: the stitched cross-relation
    composer.  ``extend`` chains each member's composed relation
    (``relation_csr`` — the capability-granted read), ``stitch`` applies
    the link's alignment matrix, ``settle`` re-binarizes accumulated path
    counts (the (OR,AND) semiring's union)."""

    def extend(self, member, value, src, dst, direction):
        if direction == "bwd":
            return value @ member.relation_csr(dst, src).T.tocsr()
        return value @ member.relation_csr(src, dst)

    def union(self, a, b):
        return a + b

    def settle(self, acc):
        acc = acc.tocsr()
        acc.data = np.ones_like(acc.data)
        return acc

    def stitch(self, link, value, reverse, n_up, n_down):
        A = link.matrix(n_up, n_down)
        return value @ (A.T.tocsr() if reverse else A)


class FederatedSession:
    """Planner/executor over a :class:`ProvCatalog`; share one per catalog
    (``catalog.session()``)."""

    def __init__(self, catalog: ProvCatalog, *,
                 cross_min_demand: Optional[int] = None,
                 cross_budget_bytes: Optional[int] = None) -> None:
        self.catalog = catalog
        # cross-boundary composed relations: route -> stitched scipy CSR,
        # in the catalog-owned store every session over this catalog shares
        store = getattr(catalog, "_cross_store", None)
        if store is None:
            store = _CrossStore(cross_budget_bytes
                                if cross_budget_bytes is not None
                                else DEFAULT_CROSS_BUDGET_BYTES)
            store.links_version = len(catalog.links)
            catalog._cross_store = store
        elif cross_budget_bytes is not None:
            store.budget_bytes = int(cross_budget_bytes)
        self._store = store
        # None = the cost-model gate (cross_route_choose); an explicit int
        # keeps the legacy fixed demand floor for this session
        self.cross_min_demand = (None if cross_min_demand is None
                                 else int(cross_min_demand))
        self.counters: Dict[str, int] = {
            "plans": 0,
            "single_index": 0,
            "federated": 0,
            "segments": 0,
            "links_crossed": 0,
            "fused_groups": 0,
            "fused_plans": 0,
            "cross_composes": 0,
            "cross_probes": 0,
        }

    # -- ref plumbing ----------------------------------------------------------
    def _member_name(self, ref: str) -> str:
        name, _ = split_ref(ref)
        if name not in self.catalog.members:
            raise FederationError(
                f"unknown index {name!r} in ref {ref!r} "
                f"(registered: {sorted(self.catalog.members)})"
            )
        return name

    def _plan_members(self, plan: QueryPlan) -> List[str]:
        names = []
        for ref in plan.refs():
            n = self._member_name(ref)
            if n not in names:
                names.append(n)
        return names

    def _unqualified(self, plan: QueryPlan) -> QueryPlan:
        strip = lambda r: None if r is None else split_ref(r)[1]  # noqa: E731
        return dataclasses.replace(
            plan, source=strip(plan.source), target=strip(plan.target),
            via=strip(plan.via), anchor=strip(plan.anchor),
        )

    def _n_rows(self, ref: str) -> int:
        return self.catalog.datasets[ref].n_rows

    # -- routing ---------------------------------------------------------------
    def _link_graph(self, reverse: bool) -> Dict[str, List[Link]]:
        """Outgoing links per member in traversal direction (``reverse``
        walks links downstream→upstream for backward propagation)."""
        out: Dict[str, List[Link]] = {}
        for link in self.catalog.links:
            key = split_ref(link.down if reverse else link.up)[0]
            out.setdefault(key, []).append(link)
        return out

    def _route(self, m0: str, m1: str, reverse: bool
               ) -> Optional[Tuple[List[str], List[Link]]]:
        """Members in topological traversal order + the links on some
        ``m0`` → ``m1`` path, or None when no link path exists."""
        adj = self._link_graph(reverse)

        def _next(link: Link) -> str:
            return split_ref(link.up if reverse else link.down)[0]

        # members reachable from m0 / co-reachable to m1
        fwd = {m0}
        frontier = [m0]
        while frontier:
            m = frontier.pop()
            for link in adj.get(m, []):
                n = _next(link)
                if n not in fwd:
                    fwd.add(n)
                    frontier.append(n)
        if m1 not in fwd:
            return None
        radj: Dict[str, List[str]] = {}
        for m, links in adj.items():
            for link in links:
                radj.setdefault(_next(link), []).append(m)
        bwd = {m1}
        frontier = [m1]
        while frontier:
            m = frontier.pop()
            for p in radj.get(m, []):
                if p not in bwd:
                    bwd.add(p)
                    frontier.append(p)
        relevant = fwd & bwd
        links = [l for m in relevant for l in adj.get(m, [])
                 if _next(l) in relevant]
        # Kahn topo order over the relevant members
        indeg = {m: 0 for m in relevant}
        for link in links:
            indeg[_next(link)] += 1
        order, ready = [], sorted(m for m, d in indeg.items() if d == 0)
        while ready:
            m = ready.pop(0)
            order.append(m)
            for link in adj.get(m, []):
                n = _next(link)
                if n in indeg:
                    indeg[n] -= 1
                    if indeg[n] == 0:
                        ready.append(n)
        if len(order) != len(relevant):
            raise FederationError(
                f"link graph has a cycle through {sorted(relevant)}; "
                "federated routing needs an acyclic member graph"
            )
        return order, links

    # -- the shared route traversal --------------------------------------------
    def _traverse(self, start_ref: str, end_ref: str, mode: str,
                  order: List[str], links: List[Link], ops, init):
        """Walk the route in member-topological order, propagating a VALUE
        (mask stack, relation, or dry True) from ``start_ref`` to
        ``end_ref``: per member, every entry value advances to every
        needed exit through ``ops.extend`` (exits reached from several
        entries ``ops.union``), then crosses each outgoing link through
        ``ops.stitch``.  Returns ``(answer, segments, crossed)``.

        This is the ONE traversal behind live mask propagation, stitched
        cross-relation composition, AND dry routing (explain /
        invalidation signatures) — parameterizing the semiring instead of
        duplicating the walk keeps the three behaviorally identical.
        """
        m0, d0 = split_ref(start_ref)
        m1, d1 = split_ref(end_ref)
        reverse = mode == "bwd"
        direction = "bwd" if reverse else "fwd"
        out_links: Dict[str, List[Link]] = {}
        for link in links:
            out_links.setdefault(
                split_ref(link.down if reverse else link.up)[0], []
            ).append(link)

        entries: Dict[str, Dict[str, object]] = {m0: {d0: init}}
        segments: List[_Segment] = []
        crossed: List[Link] = []
        answer = None
        for m in order:
            ent = entries.pop(m, None)
            if not ent:
                continue
            member = self.catalog.members[m]
            # exit datasets this member must produce values at
            exits: List[str] = []
            for link in out_links.get(m, []):
                near = split_ref(link.down if reverse else link.up)[1]
                if near not in exits:
                    exits.append(near)
            if m == m1 and d1 not in exits:
                exits.append(d1)
            exit_vals: Dict[str, object] = {}
            for x in exits:
                acc = None
                for e, val in ent.items():
                    if e == x:
                        contrib = val       # direct pass-through
                    else:
                        has_path = member.path_exists(x, e) if reverse \
                            else member.path_exists(e, x)
                        if not has_path:
                            continue
                        segments.append(_Segment(m, e, x, direction))
                        contrib = ops.extend(member, val, e, x, direction)
                    acc = contrib if acc is None else ops.union(acc, contrib)
                if acc is not None:
                    exit_vals[x] = ops.settle(acc)
            if m == m1:
                answer = exit_vals.get(d1)
            for link in out_links.get(m, []):
                near_ref, far_ref = (
                    (link.down, link.up) if reverse else (link.up, link.down))
                near_ds = split_ref(near_ref)[1]
                far_m, far_ds = split_ref(far_ref)
                val = exit_vals.get(near_ds)
                if val is None:
                    continue
                crossed.append(link)
                up_name, up_ds = split_ref(link.up)
                down_name, down_ds = split_ref(link.down)
                n_up = self.catalog.members[up_name].datasets[up_ds].n_rows
                n_down = self.catalog.members[down_name].datasets[down_ds].n_rows
                stitched = ops.stitch(link, val, reverse, n_up, n_down)
                dest = entries.setdefault(far_m, {})
                prev = dest.get(far_ds)
                dest[far_ds] = stitched if prev is None \
                    else ops.union(prev, stitched)
        return answer, segments, crossed

    # -- cross-boundary composed relations -------------------------------------
    def _crossed_signature(self, key) -> Optional[frozenset]:
        """The set of links a route would actually STITCH THROUGH right
        now, from a dry traversal (path_exists checks only, no tensor
        work) — the stitched relation depends on exactly these."""
        start, end, mode = key
        try:
            route = self._route(split_ref(start)[0], split_ref(end)[0],
                                reverse=(mode == "bwd"))
        except FederationError:         # e.g. a new link formed a cycle
            return None
        if route is None:
            return None
        _, _, crossed = self._traverse(start, end, mode, route[0], route[1],
                                       _DryOps(), True)
        return frozenset((link.up, link.down) for link in crossed)

    # -- back-compat views over the shared store (tests/introspection) ---------
    @property
    def _cross(self):
        return self._store.entries

    @property
    def _cross_bytes(self) -> int:
        return self._store.nbytes

    @property
    def _cross_failed(self) -> set:
        return self._store.failed

    @property
    def _route_demand(self) -> Dict[Tuple[str, str, str], int]:
        return self._store.demand

    @property
    def cross_budget_bytes(self) -> int:
        return self._store.budget_bytes

    def _cross_sync(self) -> None:
        """Reconcile stitched relations after the LINK set changed.

        A new link can only alter a cached route if the route would now
        stitch through a different link set (e.g. a second boundary branch
        landing on an EXISTING dataset of the route) — compare each
        entry's crossed-link signature against a fresh dry traversal and
        drop only the routes whose signature moved.  The serving pattern —
        one new link per recorded generation, landing on a brand-new
        ``requests@N`` dataset no cached route can reach — therefore keeps
        its hot stitched relations.  Member-side writes never invalidate
        (append-only DAGs, one producer per dataset)."""
        store = self._store
        if len(self.catalog.links) == store.links_version:
            return
        store.links_version = len(self.catalog.links)
        store.failed.clear()        # a new link may make a route viable
        for key in list(store.entries):
            _, signature = store.entries[key]
            if self._crossed_signature(key) != signature:
                store.drop(key)

    # -- the cost-model compose gate -------------------------------------------
    def _route_hop_stats(self, start_ref: str, end_ref: str, mode: str,
                         order: List[str], links: List[Link]):
        """Oriented per-hop :class:`RelStats` for a route, in traversal
        order (member composed relations + link alignment matrices), plus
        the summed member one-time compose estimate.  Statistics only —
        the ``relation_stats`` capability read, no tensor work.  A hop that
        cannot be priced contributes ``None`` (the gate then falls back to
        the legacy demand floor)."""
        _, segments, crossed = self._traverse(
            start_ref, end_ref, mode, order, links, _DryOps(), True)
        per_member: Dict[str, List] = {}
        for seg in segments:
            per_member.setdefault(seg.member, []).append(seg)
        out_links: Dict[str, List[Link]] = {}
        reverse = mode == "bwd"
        for link in crossed:
            out_links.setdefault(
                split_ref(link.down if reverse else link.up)[0], []
            ).append(link)
        stats: List[Optional[RelStats]] = []
        compose_ns = 0.0
        for m in order:
            member = self.catalog.members[m]
            for seg in per_member.get(m, []):
                pair = ((seg.target, seg.source) if seg.direction == "bwd"
                        else (seg.source, seg.target))
                try:
                    rel, ns = member.relation_stats(*pair)
                except (AttributeError, CapabilityError, KeyError):
                    rel, ns = None, 0.0
                if rel is not None and seg.direction == "bwd":
                    rel = RelStats(rel.cols, rel.rows, rel.nnz, rel.structured)
                stats.append(rel)
                compose_ns += ns
            for link in out_links.get(m, []):
                up_name, up_ds = split_ref(link.up)
                down_name, down_ds = split_ref(link.down)
                n_up = self.catalog.members[up_name].datasets[up_ds].n_rows
                n_down = self.catalog.members[down_name].datasets[down_ds].n_rows
                nnz = (n_up if link.alignment is None
                       else int((link.alignment >= 0).sum()))
                rows, cols = (n_down, n_up) if reverse else (n_up, n_down)
                stats.append(RelStats(rows, cols, nnz, structured=True))
        return stats, compose_ns

    def _cross_should_compose(self, key, order: List[str], links: List[Link],
                              demand: int, n_probes: int) -> bool:
        """Whether the route should flip from segment execution to the
        stitched relation NOW.  Legacy sessions (explicit
        ``cross_min_demand=``) keep the fixed demand floor; otherwise the
        cost model prices both (:func:`cross_route_choose`)."""
        if self.cross_min_demand is not None:
            return demand >= self.cross_min_demand
        start_ref, end_ref, mode = key
        stats, compose_ns = self._route_hop_stats(start_ref, end_ref, mode,
                                                  order, links)
        verdict = cross_route_choose(stats, compose_ns, n_probes, demand,
                                     budget_bytes=self._store.budget_bytes)
        return verdict["strategy"] == "stitched"

    def _compose_cross(self, start_ref: str, end_ref: str, mode: str,
                       order: List[str], links: List[Link]):
        """The stitched ``(n_start, n_end)`` relation for a cross-member
        route, as scipy CSR (via the :class:`_RelOps` semiring on the
        shared traversal): ``M[i, j] = 1`` iff start row ``i`` propagates
        to end row ``j`` — so a probe is ONE sparse matmul, the
        merged-index cost."""
        import scipy.sparse as sp

        m0, d0 = split_ref(start_ref)
        n0 = self.catalog.members[m0].datasets[d0].n_rows
        init = sp.identity(n0, dtype=np.float32, format="csr")
        answer, _, _ = self._traverse(start_ref, end_ref, mode, order, links,
                                      _RelOps(), init)
        return answer

    def _cross_probe(self, relT, masks: np.ndarray) -> np.ndarray:
        """(B, n_start) bool through the stitched relation -> (B, n_end).

        ``relT`` is cached TRANSPOSED (``(n_end, n_start)`` CSR) so the
        probe is one CSR × dense-multivector product — the identical kernel
        and memory-access pattern as a merged index's composed backward
        probe, which is what the ~1x federation benchmark bound rests on."""
        return np.asarray(relT @ masks.astype(np.float32).T).T > 0

    # -- the core: federated record propagation --------------------------------
    def _propagate(self, start_ref: str, end_ref: str,
                   masks: Optional[np.ndarray], mode: str,
                   order: Optional[List[str]] = None,
                   links: Optional[List[Link]] = None):
        """Propagate ``(B, n_start)`` probe masks from ``start_ref`` to
        ``end_ref`` along dataflow (``mode="fwd"``) or against it
        (``mode="bwd"``).  With ``masks=None`` runs DRY: no member plans
        execute, and the (segments, links) a live run would use come back
        instead — ``explain`` uses this.
        """
        dry = masks is None
        m0, d0 = split_ref(start_ref)
        m1, d1 = split_ref(end_ref)
        if order is None:
            if m0 == m1:
                order, links = [m0], []
            else:
                route = self._route(m0, m1, reverse=(mode == "bwd"))
                if route is None:
                    if dry:
                        return None
                    return np.zeros(
                        (masks.shape[0], self._n_rows(end_ref)), dtype=bool)
                order, links = route
        if dry:
            _, segments, crossed = self._traverse(
                start_ref, end_ref, mode, order, links, _DryOps(), True)
            return segments, crossed
        if m0 != m1:
            # hot-route fast path: probe the stitched cross relation once,
            # composing it when cumulative demand has paid for it.  A route
            # that failed to compose (no path, or over budget) is memoized
            # as failed so it never re-pays the compose per probe.
            self._cross_sync()
            store = self._store
            key = (start_ref, end_ref, mode)
            entry = store.get(key)
            if entry is None and HAVE_SCIPY and key not in store.failed:
                demand = store.demand.get(key, 0) + masks.shape[0]
                store.demand[key] = demand
                if self._cross_should_compose(key, order, links, demand,
                                              masks.shape[0]):
                    rel = self._compose_cross(start_ref, end_ref, mode,
                                              order, links)
                    if rel is not None:
                        rel = rel.T.tocsr()     # probe-ready: see _cross_probe
                        self.counters["cross_composes"] += 1
                        signature = self._crossed_signature(key)
                        if store.put(key, rel, signature):
                            entry = (rel, signature)
                        else:
                            store.failed.add(key)
                    else:
                        store.failed.add(key)
            if entry is not None:
                relT, signature = entry
                self.counters["cross_probes"] += 1
                self.counters["links_crossed"] += len(signature)
                return self._cross_probe(relT, masks)
        answer, _, crossed = self._traverse(
            start_ref, end_ref, mode, order, links, _MaskOps(self),
            masks.astype(bool))
        self.counters["links_crossed"] += len(crossed)
        if answer is None:
            return np.zeros((masks.shape[0], self._n_rows(end_ref)),
                            dtype=bool)
        return answer

    # -- executors -------------------------------------------------------------
    def _check_cross_supported(self, plan: QueryPlan) -> None:
        if plan.kind == "co_contributory" and plan.via is None:
            raise FederationError(
                "cross-index co_contributory needs an explicit via= dataset "
                "(the per-probe default requires one index's reach map)"
            )

    # -- cross-member attr / how walks -----------------------------------------
    def _attr_cross_perm(self, link: Link, reverse: bool) -> np.ndarray:
        """Column alignment across a boundary link, BY COLUMN NAME.

        ``perm[j]`` is the near-side column behind far-side column ``j``
        (``-1`` = the attribute has no counterpart and drops at the
        boundary).  Near/far follow traversal direction: forward crosses
        up→down, backward down→up."""
        up_name, up_ds = split_ref(link.up)
        down_name, down_ds = split_ref(link.down)
        up_cols = list(self.catalog.members[up_name].datasets[up_ds].columns)
        down_cols = list(
            self.catalog.members[down_name].datasets[down_ds].columns)
        near, far = (down_cols, up_cols) if reverse else (up_cols, down_cols)
        pos = {c: i for i, c in enumerate(near)}
        return np.asarray([pos.get(c, -1) for c in far], dtype=np.int64)

    def _route_or_none(self, plan: QueryPlan, mode: str):
        """(order, links, out_links) for the plan's source→target route, or
        None when no link path exists (the empty answer)."""
        m0 = split_ref(plan.source)[0]
        m1 = split_ref(plan.target)[0]
        reverse = mode == "bwd"
        route = self._route(m0, m1, reverse=reverse)
        if route is None:
            return None
        order, links = route
        out_links: Dict[str, List[Link]] = {}
        for link in links:
            out_links.setdefault(
                split_ref(link.down if reverse else link.up)[0], []
            ).append(link)
        return order, links, out_links

    def _link_rows(self, link: Link) -> Tuple[int, int]:
        up_name, up_ds = split_ref(link.up)
        down_name, down_ds = split_ref(link.down)
        return (self.catalog.members[up_name].datasets[up_ds].n_rows,
                self.catalog.members[down_name].datasets[down_ds].n_rows)

    def _execute_record_how(self, plan: QueryPlan) -> List:
        """Cross-member record+how: per-member multi-seed record walks
        (ONE pass per member over all its boundary entries, so shared ops
        are traced once — exactly the merged walk's trace), stitched across
        links.  Each crossing adds a synthetic ``category="link"`` hop."""
        from repro.core.query import Hop

        B = plan.n_probes
        mode = "fwd" if plan.direction == "fwd" else "bwd"
        reverse = mode == "bwd"
        hops: List[List] = [[] for _ in range(B)]
        out = np.zeros((B, self._n_rows(plan.target)), dtype=bool)
        routed = self._route_or_none(plan, mode)
        if routed is None:
            return [(np.zeros(0, dtype=np.int64), hops[b]) for b in range(B)]
        order, _, out_links = routed
        m0, d0 = split_ref(plan.source)
        m1, d1 = split_ref(plan.target)
        entries: Dict[str, Dict[str, np.ndarray]] = {
            m0: {d0: plan.rows.astype(bool)}}
        for m in order:
            ent = entries.pop(m, None)
            if not ent:
                continue
            member = self.catalog.members[m]
            self.counters["segments"] += len(ent)
            masks, mhops = member.run_record_terms(ent, mode,
                                                   collect_hops=True)
            for b in range(B):
                hops[b].extend(mhops[b])
            if m == m1 and d1 in masks:
                out = out | masks[d1]
            for link in out_links.get(m, []):
                near_ref, far_ref = (
                    (link.down, link.up) if reverse else (link.up, link.down))
                near_ds = split_ref(near_ref)[1]
                far_m, far_ds = split_ref(far_ref)
                val = masks.get(near_ds)
                if val is None or not val.any():
                    continue
                self.counters["links_crossed"] += 1
                n_up, n_down = self._link_rows(link)
                stitched = (link.stitch_up(val, n_up) if reverse
                            else link.stitch_down(val, n_down))
                counts = stitched.sum(axis=1)
                for b in np.flatnonzero(counts):
                    hops[b].append(Hop(-1, "boundary", "link", near_ref,
                                       far_ref, int(counts[b])))
                dest = entries.setdefault(far_m, {})
                prev = dest.get(far_ds)
                dest[far_ds] = stitched if prev is None else prev | stitched
        return [(np.flatnonzero(out[b]), hops[b]) for b in range(B)]

    def _execute_cells(self, plan: QueryPlan) -> List:
        """Cross-member cells / cells+how: per-member attr-TERM walks
        joined by link stitches.  Row masks cross through the link's row
        alignment; packed attribute words unpack, re-align by column name
        (:meth:`_attr_cross_perm`), and repack.  The final outer product
        (:func:`repro.core.query._cells_batch`) runs once at the target."""
        from repro.core import query as Q
        from repro.core.provtensor import pack_bitplane, unpack_bitplane

        B = plan.n_probes
        mode = "fwd" if plan.direction == "fwd" else "bwd"
        reverse = mode == "bwd"
        tgt = self.catalog.datasets[plan.target]
        hops: List[List] = [[] for _ in range(B)]
        target_terms: List = []
        routed = self._route_or_none(plan, mode)
        if routed is not None:
            order, _, out_links = routed
            m0, d0 = split_ref(plan.source)
            m1, d1 = split_ref(plan.target)
            seed = (plan.rows.astype(bool),
                    pack_bitplane(np.ascontiguousarray(
                        plan.attrs.astype(bool))))
            entries: Dict[str, Dict[str, List]] = {m0: {d0: [seed]}}
            for m in order:
                ent = entries.pop(m, None)
                if not ent:
                    continue
                member = self.catalog.members[m]
                self.counters["segments"] += len(ent)
                if plan.how:
                    terms, _, mhops = member.run_attr_terms(
                        ent, mode, collect_hops=True)
                    for b in range(B):
                        hops[b].extend(mhops[b])
                else:
                    terms, _ = member.run_attr_terms(ent, mode)
                if m == m1:
                    target_terms = terms.get(d1, [])
                for link in out_links.get(m, []):
                    near_ref, far_ref = (
                        (link.down, link.up) if reverse
                        else (link.up, link.down))
                    near_ds = split_ref(near_ref)[1]
                    far_m, far_ds = split_ref(far_ref)
                    near_terms = terms.get(near_ds, [])
                    if not near_terms:
                        continue
                    self.counters["links_crossed"] += 1
                    n_up, n_down = self._link_rows(link)
                    n_far = n_up if reverse else n_down
                    n_near_cols = self.catalog.datasets[near_ref].n_cols
                    n_far_cols = self.catalog.datasets[far_ref].n_cols
                    perm = self._attr_cross_perm(link, reverse)
                    sel = perm >= 0
                    dest = entries.setdefault(far_m, {}).setdefault(
                        far_ds, [])
                    crossed = np.zeros((B, n_far), dtype=bool)
                    for rm, aw in near_terms:
                        new_rm = (link.stitch_up(rm, n_up) if reverse
                                  else link.stitch_down(rm, n_down))
                        am = unpack_bitplane(aw, n_near_cols)
                        new_am = np.zeros((B, n_far_cols), dtype=bool)
                        if sel.any():
                            new_am[:, sel] = am[:, perm[sel]]
                        if new_rm.any() and new_am.any():
                            new_aw = pack_bitplane(new_am)
                            dest.append((new_rm, new_aw))
                            live = (new_rm.any(axis=1)
                                    & new_am.any(axis=1))
                            crossed |= new_rm & live[:, None]
                    if plan.how:
                        counts = crossed.sum(axis=1)
                        for b in np.flatnonzero(counts):
                            hops[b].append(Hop(-1, "boundary", "link",
                                               near_ref, far_ref,
                                               int(counts[b])))
        cells = _cells_batch(target_terms, B, tgt.n_rows, tgt.n_cols)
        if plan.how:
            return list(zip(cells, hops))
        return cells

    def _execute(self, plan: QueryPlan) -> List[np.ndarray]:
        """One payload per probe for a CROSS-member plan."""
        self._check_cross_supported(plan)
        self.counters["federated"] += 1
        B = plan.n_probes
        if B == 0:
            return []
        if plan.kind == "cells":
            return self._execute_cells(plan)
        if plan.kind == "record" and plan.how:
            return self._execute_record_how(plan)
        if plan.kind == "record":
            out = self._propagate(plan.source, plan.target, plan.rows,
                                  mode="fwd" if plan.direction == "fwd"
                                  else "bwd")
        elif plan.kind == "co_contributory":
            via_masks = self._propagate(plan.source, plan.via, plan.rows,
                                        mode="fwd")
            out = self._propagate(plan.via, plan.target, via_masks,
                                  mode="bwd")
        elif plan.kind == "co_dependency":
            anc = self._propagate(plan.source, plan.anchor, plan.rows,
                                  mode="bwd")
            out = self._propagate(plan.anchor, plan.target, anc, mode="fwd")
        else:
            raise FederationError(
                f"{plan.kind} plans take one dataset ref and never cross "
                "members")
        return [np.flatnonzero(m) for m in out]

    # -- the QuerySession surface ----------------------------------------------
    def run(self, plan):
        """Execute one plan (a :class:`QueryPlan` over qualified refs, or a
        builder).  Single-member plans delegate wholesale to the owning
        member's session — every plan kind, identical shapes; cross-member
        plans split, stitch, and return the same shapes."""
        plan = plan if isinstance(plan, QueryPlan) else plan.plan()
        self.counters["plans"] += 1
        names = self._plan_members(plan)
        if len(names) == 1:
            self.counters["single_index"] += 1
            return self.catalog.members[names[0]].run(self._unqualified(plan))
        per = self._execute(plan)
        return per if plan.batched else per[0]

    def run_many(self, plans: Sequence) -> List:
        """Batch execution with fuse-key fusion (same contract as
        ``QuerySession.run_many``): cross-member plans sharing a route pack
        into ONE propagation — one record pass per member segment for the
        whole group."""
        return run_many_fused(plans, self.run, self._run_fused, self.counters)

    def _run_fused(self, fused: QueryPlan) -> List:
        names = self._plan_members(fused)
        if len(names) == 1:
            member = self.catalog.members[names[0]]
            sub = self._unqualified(fused)
            self.counters["single_index"] += 1
            return member.run(sub)          # batched plan: one payload/probe
        return self._execute(fused)

    def explain(self, plan) -> Dict[str, object]:
        """The route without executing: per-segment strategy/cost from each
        owning member's planner, the links crossed, and the top-level
        verdict — never just a stitched total."""
        plan = plan if isinstance(plan, QueryPlan) else plan.plan()
        names = self._plan_members(plan)
        out: Dict[str, object] = {"plan": plan.describe()}
        if len(names) == 1:
            inner = self.catalog.members[names[0]].explain(
                self._unqualified(plan))
            out.update(inner)
            out["federated"] = False
            out["index"] = names[0]
            return out
        self._check_cross_supported(plan)
        out["federated"] = True
        out["strategy"] = "federated"
        legs: List[Tuple[str, str, str]] = []
        if plan.kind in ("record", "cells"):
            legs = [(plan.source, plan.target,
                     "fwd" if plan.direction == "fwd" else "bwd")]
        elif plan.kind == "co_contributory":
            legs = [(plan.source, plan.via, "fwd"),
                    (plan.via, plan.target, "bwd")]
        elif plan.kind == "co_dependency":
            legs = [(plan.source, plan.anchor, "bwd"),
                    (plan.anchor, plan.target, "fwd")]
        segments: List[Dict[str, object]] = []
        links: List[str] = []
        B = max(plan.n_probes, 1)
        for start, end, mode in legs:
            dry = self._propagate(start, end, None, mode=mode)
            if dry is None:
                segments.append({"leg": f"{start}->{end}", "route": None})
                continue
            segs, crossed = dry
            links.extend(f"{l.up} => {l.down}" for l in crossed)
            for seg in segs:
                if plan.kind == "cells" or plan.how:
                    # attr bitplanes / hop traces live on the per-op walk:
                    # every member segment of such a plan walks
                    segments.append({
                        "index": seg.member,
                        "segment": f"{seg.source}->{seg.target}",
                        "direction": seg.direction,
                        "strategy": "walk",
                    })
                    continue
                member = self.catalog.members[seg.member]
                n = member.datasets[seg.source].n_rows
                probe = np.zeros((B, n), dtype=bool)
                if n:
                    probe[:, 0] = True      # nominal single-row probes
                sub = QueryPlan(kind="record", source=seg.source,
                                target=seg.target, direction=seg.direction,
                                rows=probe, batched=True)
                inner = member.explain(sub)
                segments.append({
                    "index": seg.member,
                    "segment": f"{seg.source}->{seg.target}",
                    "direction": seg.direction,
                    "strategy": inner.get("strategy"),
                    **({"cost": inner["cost"]} if "cost" in inner else {}),
                })
        out["segments"] = segments
        out["links"] = links
        return out

    def stats(self) -> Dict:
        """Federation counters plus EVERY member's full session stats,
        keyed by registered index name — per-index planner and hop-cache
        counters stay attributable after federation."""
        return {
            "federation": dict(self.counters),
            "indexes": {name: member.stats()
                        for name, member in self.catalog.members.items()},
        }
