"""The QueryPlan IR — the compiled form of one provenance query.

The fluent builder (:mod:`repro.provenance.builder`) normalizes every probe
into this explicit intermediate representation; the planner/executor
(:class:`repro.provenance.session.QuerySession`) then chooses the physical
strategy (per-op vectorized walk, composed hop-cache probe, multi-path CSR
composition) per plan, and fuses plans that share a (source, target) pair
into one packed-bitplane pass.

A plan is *data*, not behaviour: row/attr probes are held as normalized
boolean mask stacks of shape ``(B, n)`` so that stacking two plans' probes
is plain ``np.concatenate`` — the whole fusion story rests on that.

Plan kinds and their Table-VII queries:

=================  ==========================================================
kind               covers
=================  ==========================================================
``record``         Q1/Q2 (``how=False``), Q5/Q6 (``how=True``)
``cells``          Q3/Q4 (``how=False``), Q7/Q8 (``how=True``)
``transformations``  Q9 (metadata only)
``co_contributory``  Q10 (``via`` optional — per-probe default otherwise)
``co_dependency``    Q11 (``anchor`` = the shared ancestor dataset d1)
=================  ==========================================================
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

__all__ = ["QueryPlan", "AmbiguousProbeWarning", "PLAN_KINDS"]

PLAN_KINDS = (
    "record",
    "cells",
    "transformations",
    "co_contributory",
    "co_dependency",
)


class AmbiguousProbeWarning(UserWarning):
    """A probe spelling whose single-vs-batch reading is ambiguous.

    The legacy free functions (``q1_forward`` …) guessed: an empty list and
    a 1-D integer ndarray silently took the single-probe path while a list
    of sets took the batch path.  The builder removes the guess with the
    explicit ``.rows(...)`` / ``.rows_batch(...)`` entry points; the legacy
    shims emit this warning whenever they have to guess.
    """


@dataclasses.dataclass(frozen=True, eq=False)
class QueryPlan:
    """One compiled provenance query.

    ``rows`` / ``attrs`` are normalized ``(B, n)`` boolean mask stacks
    (``B == 1`` for single probes; ``batched`` records whether the caller
    asked for batch-shaped results).  ``eq`` is disabled — plans carry
    ndarrays; identity is the right notion for the planner.
    """

    kind: str                           # one of PLAN_KINDS
    source: str                         # dataset the row probe lives in
    target: Optional[str] = None        # answer dataset (d2 for Q10, d3 for Q11)
    direction: str = "fwd"              # "fwd" | "bwd" (record / cells)
    rows: Optional[np.ndarray] = None   # (B, n_source) bool
    attrs: Optional[np.ndarray] = None  # (B, n_source_cols) bool (cells only)
    how: bool = False                   # collect Hop traces (Q5-Q8)
    batched: bool = False               # caller asked for batch-shaped results
    via: Optional[str] = None           # Q10 meeting dataset (None = per-probe)
    anchor: Optional[str] = None        # Q11 shared-ancestor dataset (d1)

    def __post_init__(self) -> None:
        if self.kind not in PLAN_KINDS:
            raise ValueError(f"unknown plan kind {self.kind!r}")
        if self.direction not in ("fwd", "bwd"):
            raise ValueError(f"unknown direction {self.direction!r}")
        if self.kind != "transformations" and self.rows is None:
            raise ValueError(f"{self.kind} plan needs a row probe")
        if self.kind == "cells" and self.attrs is None:
            raise ValueError("cells plan needs an attr probe")
        if self.kind in ("record", "cells") and self.target is None:
            raise ValueError(f"{self.kind} plan needs a target dataset (.to)")
        if self.kind == "co_dependency" and (
            self.anchor is None or self.target is None
        ):
            raise ValueError("co_dependency plan needs anchor (d1) and target (d3)")
        if self.kind == "co_contributory" and self.target is None:
            raise ValueError("co_contributory plan needs a target dataset (d2)")
        if self.how and self.kind not in ("record", "cells"):
            raise ValueError(f"how-provenance is undefined for {self.kind} plans")
        if (
            self.rows is not None
            and self.attrs is not None
            and self.rows.shape[0] != self.attrs.shape[0]
        ):
            raise ValueError(
                f"row batch ({self.rows.shape[0]}) and attr batch "
                f"({self.attrs.shape[0]}) disagree"
            )

    # -- planner handles ------------------------------------------------------
    @property
    def n_probes(self) -> int:
        return 0 if self.rows is None else int(self.rows.shape[0])

    def refs(self) -> Tuple[str, ...]:
        """Every dataset ref the plan touches (source, target, via, anchor).

        Refs are opaque strings to the IR: a plan compiled over one index
        carries bare dataset ids, one compiled over a
        :class:`~repro.provenance.catalog.ProvCatalog` carries
        index-qualified ``"name/dataset"`` refs — the executing session
        (``QuerySession`` vs ``FederatedSession``) owns the interpretation.
        Capability validation (``BoundaryHandle``) and federated routing
        both enumerate a plan's footprint through this.
        """
        return tuple(
            r for r in (self.source, self.target, self.via, self.anchor)
            if r is not None
        )

    def fuse_key(self) -> Tuple:
        """Plans with equal keys answer from ONE fused physical pass.

        Everything except the probe masks participates: kind, endpoints,
        direction, how, attr-presence, via/anchor.
        """
        return (
            self.kind,
            self.direction,
            self.source,
            self.target,
            self.via,
            self.anchor,
            self.how,
            self.attrs is not None,
        )

    def describe(self) -> str:
        """Compact human-readable spelling (logs / EXPLAIN output)."""
        bits = [self.kind, self.direction, f"{self.source}->{self.target}"]
        if self.rows is not None:
            bits.append(f"B={self.rows.shape[0]}")
        if self.attrs is not None:
            bits.append("attrs")
        if self.how:
            bits.append("how")
        if self.via:
            bits.append(f"via={self.via}")
        if self.anchor:
            bits.append(f"anchor={self.anchor}")
        return " ".join(bits)
