"""Mesh-sharded provenance index (ROADMAP item 1).

One :class:`~repro.core.pipeline.ProvenanceIndex` holds the whole pipeline's
provenance on one host.  This module partitions that index across ``S``
shards by CONTIGUOUS OUTPUT-ROW RANGE — the same
:func:`~repro.core.provtensor.shard_ranges` layout for every dataset, every
op tensor, and every composed hop-cache relation — and re-runs the batched
mask walkers as per-shard work joined by two collectives:

* **forward hop** — probe masks are replicated (``(B, n_in)`` is the small
  side); each shard propagates through its row-sliced tensor
  (:meth:`~repro.core.provtensor.ProvTensor.slice_rows`) producing the
  ``(B, hi-lo)`` slice of the output mask; the full stack is the
  range-ordered CONCATENATION over shards (``all_gather`` on a mesh).
* **backward hop** — each shard scatters its local ``(B, hi-lo)`` output
  slice to the full input space; the answer is the OR over shards
  (``psum > 0`` on a mesh).

Because OR over the shard contributions IS the full relation, both joins
are byte-identical to the merged single-host walk — the differential parity
suite (``tests/test_sharded_parity.py``) pins this at 1/2/4/8 shards across
every plan kind.

Two execution engines share that contract:

* ``"collective"`` — real ``jax.shard_map`` collectives over a 1-D device
  mesh (:func:`~repro.launch.mesh.make_shard_mesh`; multi-device CPU via
  ``XLA_FLAGS=--xla_force_host_platform_device_count=N``).
* ``"numpy"`` — a sequential per-shard loop with the identical join
  algebra; the fallback wherever the host exposes fewer devices than
  shards, and the reference the parity suite compares against itself.

:class:`ShardedProvenanceIndex` is a VIEW over the base index — datasets,
DAG structure, and attribute maps are shared; only the per-op tensors are
re-dressed as :class:`ShardedTensor`.  The standard
:class:`~repro.provenance.session.QuerySession` therefore runs every plan
kind (record / cells / co-queries / how-traces) over the view unchanged,
and :class:`ShardedComposedIndex` gives it a hop-cache whose entries are
per-shard relation BLOCKS (``(n_src, hi-lo)`` each), composed right-to-left
from the dst-sliced last hop so intermediates stay shard-local, with the
per-shard storage backend chosen from SHARD-LOCAL nnz
(:meth:`~repro.core.costmodel.RelStats.from_slot_range`).

Federation seam — :meth:`ShardedProvenanceIndex.as_catalog` registers each
shard as a :class:`~repro.provenance.catalog.ProvCatalog` member holding its
composed ``src → dst`` block as ONE recorded op, stitched by range-alignment
links (``alignment[j] = j - lo`` inside the shard's range, ``-1`` outside)
into a full-width gather member.  Cross-shard forward/backward probes then
ride the PR 4 federation machinery — segment walk, multi-link OR, stitched
cross-relation cache — completely unchanged.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.compose import (
    HAVE_SCIPY,
    chain_gather,
    compose_pair_csr,
    op_csr,
    path_tensors,
)
from repro.core.costmodel import (
    DENSITY_THRESHOLD,
    CostModel,
    RelStats,
    compose_est,
)
from repro.core.pipeline import ProvenanceIndex
from repro.core.provtensor import ProvTensor, shard_ranges

__all__ = [
    "ShardedTensor",
    "ShardedProvenanceIndex",
    "ShardedComposedIndex",
]


# ---------------------------------------------------------------------------
# The shard_map collective engine
# ---------------------------------------------------------------------------
class _CollectiveEngine:
    """Batched mask hops as ``shard_map`` collectives over a 1-D mesh.

    Per (tensor, slot) the valid link pairs of every shard pad to one
    ``(S, L)`` block (the mesh needs equal block sizes); a forward hop is a
    per-device gather+scatter followed by ``all_gather``, a backward hop a
    scatter into the full input space followed by ``psum``.  Compiled
    executables memoize on the shape tuple."""

    def __init__(self, mesh, axis: str = "shards") -> None:
        self.mesh = mesh
        self.axis = axis
        self._fwd = {}
        self._bwd = {}

    @property
    def n_devices(self) -> int:
        return int(np.prod(self.mesh.devices.shape))

    @staticmethod
    def _shard_map(fn, **kwargs):
        # The outputs are replicated BY CONSTRUCTION (all_gather / psum),
        # but the static replication checker cannot see through the
        # scatter ops, so it must be disabled; its keyword has been
        # renamed across jax releases.
        import jax

        smap = jax.shard_map if hasattr(jax, "shard_map") else None
        if smap is None:  # pragma: no cover - older jax
            from jax.experimental.shard_map import shard_map as smap
        for flag in ("check_vma", "check_rep"):
            try:
                return smap(fn, **kwargs, **{flag: False})
            except TypeError:
                continue
        return smap(fn, **kwargs)  # pragma: no cover

    def _padded(self, st: "ShardedTensor", inp: int):
        """(out_idx, in_idx, valid) int32/bool ``(S, L)`` blocks + widths."""
        cache = st._collective
        if inp not in cache:
            pairs = []
            for shard in st.shards:
                out, inn = shard._slot_pairs(inp)
                out = np.asarray(out, dtype=np.int32)
                inn = np.asarray(inn, dtype=np.int32)
                keep = (out >= 0) & (inn >= 0)
                pairs.append((out[keep], inn[keep]))
            S = len(pairs)
            L = max(1, max(len(o) for o, _ in pairs))
            out_idx = np.zeros((S, L), dtype=np.int32)
            in_idx = np.zeros((S, L), dtype=np.int32)
            valid = np.zeros((S, L), dtype=bool)
            for s, (o, i) in enumerate(pairs):
                out_idx[s, : len(o)] = o
                in_idx[s, : len(o)] = i
                valid[s, : len(o)] = True
            widths = [hi - lo for lo, hi in st.ranges]
            cache[inp] = (out_idx, in_idx, valid, widths, max(max(widths), 1))
        return cache[inp]

    def _fwd_fn(self, S: int, L: int, Pw: int, B: int, n_in: int):
        key = (S, L, Pw, B, n_in)
        if key not in self._fwd:
            import jax
            import jax.numpy as jnp
            from jax.sharding import PartitionSpec as P

            axis = self.axis

            def local(masks, out_idx, in_idx, valid):
                o, i, v = out_idx[0], in_idx[0], valid[0]
                vals = masks[:, i] & v[None, :]
                loc = jnp.zeros((B, Pw), dtype=bool).at[:, o].max(vals)
                return jax.lax.all_gather(loc, axis)

            f = self._shard_map(
                local, mesh=self.mesh,
                in_specs=(P(), P(axis), P(axis), P(axis)),
                out_specs=P())
            self._fwd[key] = jax.jit(f)
        return self._fwd[key]

    def _bwd_fn(self, S: int, L: int, Pw: int, B: int, n_in: int):
        key = (S, L, Pw, B, n_in)
        if key not in self._bwd:
            import jax
            import jax.numpy as jnp
            from jax.sharding import PartitionSpec as P

            axis = self.axis

            def local(local_masks, out_idx, in_idx, valid):
                lm, o, i, v = local_masks[0], out_idx[0], in_idx[0], valid[0]
                vals = lm[:, o] & v[None, :]
                contrib = jnp.zeros((B, n_in), dtype=bool).at[:, i].max(vals)
                return jax.lax.psum(contrib.astype(jnp.int32), axis) > 0

            f = self._shard_map(
                local, mesh=self.mesh,
                in_specs=(P(axis), P(axis), P(axis), P(axis)),
                out_specs=P())
            self._bwd[key] = jax.jit(f)
        return self._bwd[key]

    def forward(self, st: "ShardedTensor", inp: int,
                masks: np.ndarray) -> np.ndarray:
        out_idx, in_idx, valid, widths, Pw = self._padded(st, inp)
        S, L = out_idx.shape
        B = masks.shape[0]
        fn = self._fwd_fn(S, L, Pw, B, st.n_in[inp])
        gathered = np.asarray(fn(masks, out_idx, in_idx, valid))  # (S, B, Pw)
        return np.concatenate(
            [gathered[s, :, :w] for s, w in enumerate(widths)], axis=1)

    def backward(self, st: "ShardedTensor", inp: int,
                 masks: np.ndarray) -> np.ndarray:
        out_idx, in_idx, valid, widths, Pw = self._padded(st, inp)
        S, L = out_idx.shape
        B = masks.shape[0]
        local = np.zeros((S, B, Pw), dtype=bool)
        for s, (lo, hi) in enumerate(st.ranges):
            local[s, :, : hi - lo] = masks[:, lo:hi]
        fn = self._bwd_fn(S, L, Pw, B, st.n_in[inp])
        return np.asarray(fn(local, out_idx, in_idx, valid))


# ---------------------------------------------------------------------------
# Row-range-sharded tensors and the op/index views over them
# ---------------------------------------------------------------------------
class ShardedTensor:
    """One op tensor partitioned into row-range shards, answering the full
    :class:`ProvTensor` mask surface through the shard join algebra.
    Slot statistics and lazy mirrors delegate to the base tensor (they
    describe the SAME relation)."""

    def __init__(self, base: ProvTensor, n_shards: int,
                 engine: Optional[_CollectiveEngine] = None) -> None:
        self.base = base
        self.n_shards = int(n_shards)
        self.ranges = shard_ranges(base.n_out, n_shards)
        self.shards = [base.slice_rows(lo, hi) for lo, hi in self.ranges]
        self.engine = engine
        self._collective: Dict = {}      # engine pads, keyed by slot

    # -- delegated shape / statistics / mirrors ------------------------------
    @property
    def n_out(self) -> int:
        return self.base.n_out

    @property
    def n_in(self) -> tuple:
        return self.base.n_in

    @property
    def k(self) -> int:
        return self.base.k

    @property
    def structured(self) -> bool:
        return self.base.structured

    @property
    def nnz(self) -> int:
        return self.base.nnz

    @property
    def coo(self) -> np.ndarray:
        return self.base.coo

    def slot_structure(self, inp: int):
        return self.base.slot_structure(inp)

    def slot_gather(self, inp: int):
        return self.base.slot_gather(inp)

    def slot_nnz(self, inp: int) -> int:
        return self.base.slot_nnz(inp)

    def slot_nnz_range(self, inp: int, lo: int, hi: int) -> int:
        return self.base.slot_nnz_range(inp, lo, hi)

    def slot_shape(self, inp: int) -> tuple:
        return self.base.slot_shape(inp)

    def slot_density(self, inp: int) -> float:
        return self.base.slot_density(inp)

    def _slot_pairs(self, inp: int):
        return self.base._slot_pairs(inp)

    def fwd(self, inp: int):
        return self.base.fwd(inp)

    def bwd(self, inp: int):
        return self.base.bwd(inp)

    def bitplane_fwd(self, inp: int) -> np.ndarray:
        return self.base.bitplane_fwd(inp)

    def bitplane_bwd(self, inp: int) -> np.ndarray:
        return self.base.bitplane_bwd(inp)

    def nbytes(self, include_index: bool = True) -> int:
        return self.base.nbytes(include_index)

    # -- the sharded mask hops ----------------------------------------------
    def forward_mask_batch(self, inp: int, in_masks: np.ndarray) -> np.ndarray:
        masks = np.asarray(in_masks, dtype=bool)
        if self.engine is not None:
            return self.engine.forward(self, inp, masks)
        return np.concatenate(
            [t.forward_mask_batch(inp, masks) for t in self.shards], axis=1)

    def backward_mask_batch(self, inp: int, out_masks: np.ndarray) -> np.ndarray:
        masks = np.asarray(out_masks, dtype=bool)
        if self.engine is not None:
            return self.engine.backward(self, inp, masks)
        out = np.zeros((masks.shape[0], self.n_in[inp]), dtype=bool)
        for (lo, hi), t in zip(self.ranges, self.shards):
            out |= t.backward_mask_batch(inp, masks[:, lo:hi])
        return out

    def forward_mask(self, inp: int, in_mask: np.ndarray) -> np.ndarray:
        return self.forward_mask_batch(
            inp, np.asarray(in_mask, dtype=bool)[None, :])[0]

    def backward_mask(self, inp: int, out_mask: np.ndarray) -> np.ndarray:
        return self.backward_mask_batch(
            inp, np.asarray(out_mask, dtype=bool)[None, :])[0]

    def forward_rows(self, inp: int, rows) -> np.ndarray:
        pieces = [t.forward_rows(inp, rows) + lo
                  for (lo, _), t in zip(self.ranges, self.shards)]
        return np.unique(np.concatenate(pieces)) if pieces else \
            np.zeros(0, dtype=np.int64)

    def backward_rows(self, inp: int, rows) -> np.ndarray:
        rows = np.asarray(list(rows) if not isinstance(rows, np.ndarray)
                          else rows)
        if rows.dtype == bool:
            rows = np.flatnonzero(rows)
        rows = rows.astype(np.int64).reshape(-1)
        rows = np.where(rows < 0, rows + self.n_out, rows)
        pieces = []
        for (lo, hi), t in zip(self.ranges, self.shards):
            local = rows[(rows >= lo) & (rows < hi)] - lo
            pieces.append(t.backward_rows(inp, local))
        return np.unique(np.concatenate(pieces)) if pieces else \
            np.zeros(0, dtype=np.int64)

    def __repr__(self) -> str:
        return (f"ShardedTensor(n_out={self.n_out}, n_in={self.n_in}, "
                f"shards={self.n_shards}, "
                f"engine={'collective' if self.engine else 'numpy'})")


@dataclasses.dataclass
class _ShardedOp:
    """Op-record view: same identity/metadata, sharded tensor."""

    op_id: int
    info: object
    tensor: ShardedTensor
    input_ids: List[str]
    output_id: str


# ---------------------------------------------------------------------------
# The sharded hop-cache
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _ShardBlock:
    """One shard's ``(n_src, hi-lo)`` slice of a composed relation."""

    kind: str               # "csr" (scipy) | "dense" (bool ndarray)
    mat: object
    lo: int
    hi: int
    nnz: int
    _fwd_t: object = None   # (width, n_src) CSR mirror for forward probes

    def fwd_t(self):
        """The transposed CSR mirror — forward probes as a row-major spmm
        (CSC-orientation products are several times slower in scipy)."""
        if self._fwd_t is None:
            self._fwd_t = self.mat.T.tocsr()
        return self._fwd_t

    def nbytes(self) -> int:
        if self.kind == "dense":
            return int(self.mat.nbytes)
        return int(self.mat.data.nbytes + self.mat.indices.nbytes
                   + self.mat.indptr.nbytes)


@dataclasses.dataclass
class _ShardEntry:
    blocks: List[_ShardBlock]
    rows: int               # n_src
    cols: int               # n_dst
    nbytes: int


def _dense_rel(tensor: ProvTensor, slot: int) -> np.ndarray:
    """Dense bool (n_in, n_out) relation of one slot — the scipy-free
    composition fallback (small indexes only)."""
    out, inn = tensor._slot_pairs(slot)
    valid = (np.asarray(out) >= 0) & (np.asarray(inn) >= 0)
    dense = np.zeros((tensor.n_in[slot], tensor.n_out), dtype=bool)
    dense[np.asarray(inn)[valid], np.asarray(out)[valid]] = True
    return dense


class ShardedComposedIndex:
    """Hop-cache over a :class:`ShardedProvenanceIndex`: each ``(src, dst)``
    relation is held as per-shard column blocks.

    Blocks compose RIGHT-TO-LEFT from the dst-row-sliced last hop, so every
    intermediate is ``(n_i, hi-lo)`` — per-shard compose work scales with
    the shard's slice, not the full relation.  The per-shard storage backend
    (scipy CSR vs dense bool) follows the cost model's SHARD-LOCAL density
    estimate (:meth:`RelStats.from_slot_range` folded through
    :func:`compose_est`), so a shard whose range is dense can go dense while
    its sparse neighbors stay CSR.  Probes join exactly like the walkers:
    forward concatenates block answers in range order, backward ORs them.

    Same planner surface as :class:`~repro.core.hopcache.ComposedIndex`
    (``probe_forward`` / ``probe_backward`` / ``contains`` /
    ``memory_budget_bytes`` / ``costmodel`` / ``stats``), so
    ``QuerySession`` routes through it unchanged.  Append-safe for the same
    reason the merged hop-cache is: one producer per dataset means recorded
    appends cannot alter an existing pair's relation.
    """

    def __init__(self, sharded: "ShardedProvenanceIndex",
                 memory_budget_bytes: int = 64 << 20) -> None:
        self.sharded = sharded
        self.index = sharded          # planner surface parity (stats/name)
        self.memory_budget_bytes = int(memory_budget_bytes)
        self.costmodel = CostModel(sharded)
        self._cache: "OrderedDict[Tuple[str, str], _ShardEntry]" = OrderedDict()
        self._bytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    # -- composition ---------------------------------------------------------
    def _shard_chain_est(self, chain, lo: int, hi: int) -> RelStats:
        """Estimated stats of the composed relation restricted to dst rows
        ``[lo, hi)``: shard-local stats for the final hop, full-slot stats
        folded in for the upstream hops."""
        last_op, last_slot = chain[-1]
        acc = RelStats.from_slot_range(last_op.tensor, last_slot, lo, hi)
        for op, slot in reversed(chain[:-1]):
            acc = compose_est(RelStats.from_slot(op.tensor, slot), acc)
        return acc

    def _compose_block(self, chain, n_src: int, lo: int, hi: int,
                       g: Optional[np.ndarray]) -> _ShardBlock:
        width = hi - lo
        if g is not None:
            # fully structured chain: the closed-form dst→src gather, sliced
            # to this shard's window — O(width) work, no matmul at all
            gs = g[lo:hi]
            dst_local = np.flatnonzero(gs >= 0)
            src_rows = gs[dst_local]
            nnz = len(dst_local)
            if HAVE_SCIPY:
                import scipy.sparse as sp

                mat = sp.csr_matrix(
                    (np.ones(nnz, dtype=np.float32), (src_rows, dst_local)),
                    shape=(n_src, width))
                return _ShardBlock("csr", mat, lo, hi, nnz)
            dense = np.zeros((n_src, width), dtype=bool)
            dense[src_rows, dst_local] = True
            return _ShardBlock("dense", dense, lo, hi, nnz)
        est = self._shard_chain_est(chain, lo, hi)
        want_dense = (not HAVE_SCIPY) or est.density >= DENSITY_THRESHOLD
        last_op, last_slot = chain[-1]
        sliced = last_op.tensor.base.slice_rows(lo, hi) \
            if isinstance(last_op.tensor, ShardedTensor) \
            else last_op.tensor.slice_rows(lo, hi)
        if want_dense:
            acc = _dense_rel(sliced, last_slot)
            for op, slot in reversed(chain[:-1]):
                step = _dense_rel(
                    op.tensor.base if isinstance(op.tensor, ShardedTensor)
                    else op.tensor, slot)
                acc = (step.astype(np.uint8) @ acc.astype(np.uint8)) > 0
            return _ShardBlock("dense", acc, lo, hi,
                               int(np.count_nonzero(acc)))
        acc = op_csr(sliced, last_slot)
        for op, slot in reversed(chain[:-1]):
            base = op.tensor.base if isinstance(op.tensor, ShardedTensor) \
                else op.tensor
            acc = compose_pair_csr(op_csr(base, slot), acc)
        return _ShardBlock("csr", acc, lo, hi, int(acc.nnz))

    def _entry(self, src: str, dst: str) -> Optional[_ShardEntry]:
        key = (src, dst)
        entry = self._cache.get(key)
        if entry is not None:
            self.hits += 1
            self._cache.move_to_end(key)
            return entry
        base = self.sharded.base
        if src not in base.datasets or dst not in base.datasets:
            raise KeyError(f"unknown dataset in relation {src!r} -> {dst!r}")
        self.misses += 1
        n_src = base.datasets[src].n_rows
        n_dst = base.datasets[dst].n_rows
        try:
            chain = path_tensors(base, src, dst)
        except KeyError:
            return None
        ranges = shard_ranges(n_dst, self.sharded.n_shards)
        if not chain:           # src == dst: identity, sliced per shard
            g = np.arange(n_dst, dtype=np.int32)
        else:
            g = chain_gather(chain)
        blocks = [self._compose_block(chain, n_src, lo, hi, g)
                  for lo, hi in ranges]
        entry = _ShardEntry(blocks=blocks, rows=n_src, cols=n_dst,
                            nbytes=sum(b.nbytes() for b in blocks))
        self._cache[key] = entry
        self._bytes += entry.nbytes
        while self._bytes > self.memory_budget_bytes and len(self._cache) > 1:
            _, evicted = self._cache.popitem(last=False)
            self._bytes -= evicted.nbytes
            self.evictions += 1
        return entry

    # -- planner surface -----------------------------------------------------
    def contains(self, src: str, dst: str) -> bool:
        return (src, dst) in self._cache

    def probe_forward(self, masks, src: str, dst: str) -> np.ndarray:
        """(B, |src|) bool -> (B, |dst|): per-shard block probes concatenated
        in range order.  No path -> all-empty (the walkers' convention).
        The probe-mask transpose/float conversion is hoisted out of the
        per-block loop — it is the replicated input every shard shares."""
        masks = np.atleast_2d(np.asarray(masks, dtype=bool))
        entry = self._entry(src, dst)
        if entry is None:
            return np.zeros(
                (masks.shape[0],
                 self.sharded.base.datasets[dst].n_rows), dtype=bool)
        m_t = np.ascontiguousarray(masks.T, dtype=np.float32)
        return np.concatenate(
            [self._block_forward(b, m_t) for b in entry.blocks], axis=1)

    def probe_backward(self, masks, dst: str, src: str) -> np.ndarray:
        """(B, |dst|) bool -> (B, |src|): per-shard block probes OR-reduced."""
        masks = np.atleast_2d(np.asarray(masks, dtype=bool))
        entry = self._entry(src, dst)
        if entry is None:
            return np.zeros(
                (masks.shape[0],
                 self.sharded.base.datasets[src].n_rows), dtype=bool)
        m_t = np.ascontiguousarray(masks.T, dtype=np.float32)
        out = np.zeros((masks.shape[0], entry.rows), dtype=bool)
        for b in entry.blocks:
            out |= self._block_backward(b, m_t[b.lo: b.hi])
        return out

    @staticmethod
    def _block_forward(b: _ShardBlock, m_t: np.ndarray) -> np.ndarray:
        """``m_t``: the (n_src, B) float32 pre-transposed probe masks."""
        if b.kind == "dense":
            return (m_t.T @ b.mat) > 0
        return np.asarray((b.fwd_t() @ m_t).T) > 0

    @staticmethod
    def _block_backward(b: _ShardBlock, local_t: np.ndarray) -> np.ndarray:
        """``local_t``: this shard's (width, B) float32 output-slice masks."""
        if b.kind == "dense":
            return (local_t.T @ b.mat.T) > 0
        return np.asarray((b.mat @ local_t).T) > 0

    def relation_csr(self, src: str, dst: str):
        """The full composed relation reassembled from the shard blocks
        (scipy CSR) — parity checks and the federation hook."""
        if not HAVE_SCIPY:
            raise ImportError("relation_csr requires scipy")
        import scipy.sparse as sp

        entry = self._entry(src, dst)
        if entry is None:
            return sp.csr_matrix((self.sharded.base.datasets[src].n_rows,
                                  self.sharded.base.datasets[dst].n_rows),
                                 dtype=np.float32)
        mats = []
        for b in entry.blocks:
            mats.append(sp.csr_matrix(b.mat, dtype=np.float32)
                        if b.kind == "dense" else b.mat)
        return sp.hstack(mats, format="csr")

    def stats(self) -> Dict[str, object]:
        per_kind = {"csr": 0, "dense": 0}
        for entry in self._cache.values():
            for b in entry.blocks:
                per_kind[b.kind] += 1
        return {
            "index": self.sharded.name,
            "n_shards": self.sharded.n_shards,
            "entries": len(self._cache),
            "blocks_csr": per_kind["csr"],
            "blocks_dense": per_kind["dense"],
            "bytes": self._bytes,
            "budget_bytes": self.memory_budget_bytes,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
        }


# ---------------------------------------------------------------------------
# The sharded index view
# ---------------------------------------------------------------------------
class ShardedProvenanceIndex:
    """Row-range-sharded view over a :class:`ProvenanceIndex`.

    ``engine="auto"`` runs ``shard_map`` collectives when the host mesh has
    at least ``n_shards`` devices, else the sequential per-shard engine
    (identical answers).  The view tracks base appends: ops recorded on the
    base after construction are wrapped on next access."""

    def __init__(self, base: ProvenanceIndex, n_shards: int, *,
                 engine: str = "auto", mesh=None) -> None:
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.base = base
        self.n_shards = int(n_shards)
        self.engine_name, self._engine = self._make_engine(engine, mesh)
        self._wrapped: List[_ShardedOp] = []
        self._composed: Optional[ShardedComposedIndex] = None
        self._session = None

    def _make_engine(self, engine: str, mesh):
        if engine == "numpy":
            return "numpy", None
        if engine not in ("auto", "collective"):
            raise ValueError(f"unknown engine {engine!r}")
        try:
            if mesh is None:
                from repro.launch.mesh import make_shard_mesh

                mesh = make_shard_mesh(self.n_shards)
        except Exception:  # jax missing/broken: the view still works
            mesh = None
        if mesh is None:
            if engine == "collective":
                raise RuntimeError(
                    f"collective engine needs >= {self.n_shards} devices "
                    "(set XLA_FLAGS=--xla_force_host_platform_device_count=N "
                    "before jax initializes)")
            return "numpy", None
        return "collective", _CollectiveEngine(mesh)

    # -- view plumbing -------------------------------------------------------
    @property
    def name(self) -> str:
        return f"{self.base.name}@shard{self.n_shards}"

    @property
    def datasets(self):
        return self.base.datasets

    @property
    def producer(self):
        return self.base.producer

    @property
    def consumers(self):
        return self.base.consumers

    @property
    def version(self) -> int:
        return self.base.version

    @property
    def ops(self) -> List[_ShardedOp]:
        for op in self.base.ops[len(self._wrapped):]:
            self._wrapped.append(_ShardedOp(
                op_id=op.op_id,
                info=op.info,
                tensor=ShardedTensor(op.tensor, self.n_shards, self._engine),
                input_ids=list(op.input_ids),
                output_id=op.output_id,
            ))
        return self._wrapped

    def _wrap(self, base_ops) -> List[_ShardedOp]:
        ops = self.ops
        return [ops[op.op_id] for op in base_ops]

    def downstream_ops(self, dataset_id: str) -> List[_ShardedOp]:
        return self._wrap(self.base.downstream_ops(dataset_id))

    def upstream_ops(self, dataset_id: str) -> List[_ShardedOp]:
        return self._wrap(self.base.upstream_ops(dataset_id))

    def path_exists(self, src: str, dst: str) -> bool:
        return self.base.path_exists(src, dst)

    def sources(self) -> List[str]:
        return self.base.sources()

    def sinks(self) -> List[str]:
        return self.base.sinks()

    def ranges(self, dataset_id: str) -> List[Tuple[int, int]]:
        """This dataset's shard layout — the partitioning contract every
        tensor slice, hop-cache block, and catalog link follows."""
        return shard_ranges(self.base.datasets[dataset_id].n_rows,
                            self.n_shards)

    def composed(self, **kwargs) -> ShardedComposedIndex:
        if self._composed is None:
            self._composed = ShardedComposedIndex(self, **kwargs)
        elif kwargs:
            raise ValueError("composed() already configured; use composed()")
        return self._composed

    def session(self, **kwargs):
        from repro.provenance.session import QuerySession

        if self._session is None:
            self._session = QuerySession(self, **kwargs)
        elif kwargs:
            raise ValueError("session() already configured; use session()")
        return self._session

    def stats(self) -> Dict[str, object]:
        out = self.base.stats()
        out["n_shards"] = self.n_shards
        out["engine"] = self.engine_name
        return out

    def __repr__(self) -> str:
        return (f"ShardedProvenanceIndex({self.base.name!r}, "
                f"n_shards={self.n_shards}, engine={self.engine_name})")

    # -- federation seam -----------------------------------------------------
    def as_catalog(self, src: str, dst: str, *,
                   root: str = "root", gather: str = "gather"):
        """Register the shards of the composed ``src → dst`` relation as
        :class:`~repro.provenance.catalog.ProvCatalog` members stitched by
        range-alignment links.

        Member graph (acyclic, so federation routing accepts it)::

            root/src --identity--> shard{s}/src --op--> shard{s}/dst@local
                 shard{s}/dst@local --alignment--> gather/dst

        Each shard member is a real single-op :class:`ProvenanceIndex`
        whose tensor is that shard's composed relation block; the
        range alignment maps global dst row ``j`` to shard-local ``j - lo``
        inside ``[lo, hi)`` and ``-1`` outside.  Forward probes from
        ``root/src`` fan out over the identity links, answer per shard, and
        OR into ``gather/dst`` over the S alignment links; backward probes
        ride the same links in reverse — all through the unchanged PR 4
        federation machinery (including its stitched cross-relation cache).
        """
        from repro.core.opcat import AttrMap, CaptureInfo, OpCategory
        from repro.dataprep.table import Table
        from repro.provenance.catalog import ProvCatalog

        if src == dst:
            raise ValueError("as_catalog needs distinct src and dst datasets")
        base = self.base
        n_src = base.datasets[src].n_rows
        n_dst = base.datasets[dst].n_rows

        def _placeholder(n: int) -> Table:
            return Table(columns=["_row"], data=np.zeros((n, 1), np.float32),
                         null=None, index=None)

        src_table = base.datasets[src].table or _placeholder(n_src)
        catalog = ProvCatalog(f"{base.name}-sharded")
        root_idx = ProvenanceIndex(root)
        root_idx.add_source(src, src_table)
        catalog.register(root, root_idx)
        gather_idx = ProvenanceIndex(gather)
        gather_idx.add_source(dst, base.datasets[dst].table
                              or _placeholder(n_dst))
        catalog.register(gather, gather_idx)

        entry = self.composed()._entry(src, dst)
        if entry is None:
            raise KeyError(f"no dataflow path {src} -> {dst}")
        local_ds = f"{dst}@local"
        for s, block in enumerate(entry.blocks):
            lo, hi = block.lo, block.hi
            member = ProvenanceIndex(f"shard{s}")
            member.add_source(src, src_table)
            if block.kind == "dense":
                src_rows, dst_local = np.nonzero(block.mat)
            else:
                coo = block.mat.tocoo()
                src_rows, dst_local = coo.row, coo.col
            links = np.stack([dst_local.astype(np.int32),
                              src_rows.astype(np.int32)], axis=1)
            info = CaptureInfo(
                op_name=f"shard{s}:{src}->{dst}",
                category=OpCategory.HAUGMENT,
                contextual=False,
                n_out=hi - lo,
                n_in=[n_src],
                links=links,
                attr_maps=[AttrMap(kind="identity")],
            )
            member.record([src], local_ds, _placeholder(hi - lo), info)
            catalog.register(f"shard{s}", member)
            catalog.link(f"{root}/{src}", f"shard{s}/{src}")
            alignment = np.full(n_dst, -1, dtype=np.int64)
            alignment[lo:hi] = np.arange(hi - lo, dtype=np.int64)
            catalog.link(f"shard{s}/{local_ds}", f"{gather}/{dst}",
                         alignment=alignment)
        return catalog
