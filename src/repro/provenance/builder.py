"""The fluent, lazy query builder: ``prov(index)``.

One chain spells any Table-VII query; nothing executes until ``.run()``
(or until the compiled :class:`~repro.provenance.plan.QueryPlan` from
``.plan()`` is handed to a :class:`~repro.provenance.session.QuerySession`):

    from repro.provenance import prov

    prov(index).source("D_l").rows([0, 3]).forward().to(sink).run()      # Q1
    prov(index).source(sink).rows([0]).backward().to("D_l").run()        # Q2
    prov(index).source("D_l").rows([0]).attrs([1]).forward().to(sink)    # Q3
    ... .how()                                                           # Q5-Q8
    prov(index).source(sink).transformations().run()                     # Q9
    prov(index).source("D_l").rows([0]).co_contributory("D_r").run()     # Q10
    prov(index).source(mid).rows([0]).co_dependency("D_l", sink).run()   # Q11

Batch probes are EXPLICIT — ``.rows_batch([...])`` / ``.attrs_batch([...])``
— which removes the legacy ``is_probe_batch`` guess (an empty list or a 1-D
integer ndarray is always a single probe here, a batch is always a batch).

The same builder spells FEDERATED queries: hand :func:`prov` a
:class:`~repro.provenance.catalog.ProvCatalog` instead of an index and use
index-qualified refs — ``prov(catalog).source("prep/raw_users").rows([...])
.forward().to("serve/responses@0").run()`` compiles to the identical
:class:`QueryPlan` IR (refs are opaque strings to the plan) and executes
through the catalog's shared
:class:`~repro.provenance.federation.FederatedSession`.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.provenance.plan import QueryPlan

__all__ = ["prov", "ProvQuery"]


def _unknown_dataset(holder, dataset_id: str) -> KeyError:
    hint = ""
    if hasattr(holder, "resolve"):          # a ProvCatalog
        hint = (" (catalog refs are index-qualified: 'index/dataset', "
                f"registered indexes: {sorted(holder.members)})")
    return KeyError(f"unknown dataset {dataset_id!r}{hint}")


def _single_mask(rows, n: int, what: str) -> np.ndarray:
    """One probe -> (n,) bool.  Accepts a bool mask, an iterable of ints, or
    a 1-D integer ndarray.  Never guesses batch."""
    if isinstance(rows, np.ndarray):
        if rows.ndim != 1:
            raise ValueError(
                f".{what}(...) takes ONE probe; use .{what}_batch(...) for a "
                f"{rows.ndim}-D stack"
            )
        if rows.dtype == bool:
            if rows.shape[0] != n:
                raise ValueError(
                    f".{what}(...): bool mask has {rows.shape[0]} entries, "
                    f"dataset has {n}"
                )
            return rows.copy()
    m = np.zeros(n, dtype=bool)
    idx = np.asarray(list(rows), dtype=np.int64)
    if idx.size:
        m[idx] = True
    return m


def _batch_masks(batch, n: int, what: str) -> np.ndarray:
    """A batch of probes -> (B, n) bool.  Accepts a 2-D bool mask stack, a
    2-D integer index array, or a list/tuple of probe sets."""
    if isinstance(batch, np.ndarray):
        if batch.ndim != 2:
            raise ValueError(
                f".{what}_batch(...) takes a batch; use .{what}(...) for a "
                "single probe"
            )
        if batch.dtype == bool:
            if batch.shape[1] != n:
                raise ValueError(
                    f".{what}_batch(...): mask stack is (B, {batch.shape[1]}), "
                    f"dataset has {n}"
                )
            return batch.copy()
        out = np.zeros((batch.shape[0], n), dtype=bool)
        out[np.arange(batch.shape[0])[:, None], batch.astype(np.int64)] = True
        return out
    if not isinstance(batch, (list, tuple)):
        raise ValueError(f".{what}_batch(...) takes a list of probe sets")
    if len(batch) == 0:
        return np.zeros((0, n), dtype=bool)  # an EMPTY batch, unambiguously
    return np.stack([_single_mask(p, n, what) for p in batch], axis=0)


class ProvQuery:
    """Mutable fluent builder over one :class:`ProvenanceIndex`.

    Every method returns ``self``; ``.plan()`` validates + compiles to the
    immutable :class:`QueryPlan`; ``.run(session=None)`` executes it through
    the given (default: the index's shared) :class:`QuerySession`.
    """

    def __init__(self, index) -> None:
        self._index = index
        self._source: Optional[str] = None
        self._rows = None
        self._rows_batched = False
        self._attrs = None
        self._attrs_batched = False
        self._direction: Optional[str] = None
        self._target: Optional[str] = None
        self._how = False
        self._kind: Optional[str] = None
        self._via: Optional[str] = None
        self._anchor: Optional[str] = None

    # -- probe anchoring ------------------------------------------------------
    def source(self, dataset_id: str) -> "ProvQuery":
        """The dataset the row probe lives in (probe origin, either end).
        Over a catalog, an index-qualified ref (``"prep/raw_users"``)."""
        if dataset_id not in self._index.datasets:
            raise _unknown_dataset(self._index, dataset_id)
        self._source = dataset_id
        return self

    def rows(self, rows) -> "ProvQuery":
        """ONE probe set: iterable of row indices, 1-D int ndarray, or a
        1-D bool mask.  Result is single-shaped (one index array)."""
        self._rows, self._rows_batched = rows, False
        return self

    def rows_batch(self, batch) -> "ProvQuery":
        """A BATCH of probe sets (list of sets / 2-D mask or index stack).
        Result is batch-shaped (one list entry per probe), answered in one
        fused physical pass."""
        self._rows, self._rows_batched = batch, True
        return self

    def attrs(self, attrs) -> "ProvQuery":
        """ONE attribute probe (makes the plan attribute-level, Q3/Q4/Q7/Q8).
        With ``.rows_batch`` the attr set broadcasts over the row batch."""
        self._attrs, self._attrs_batched = attrs, False
        return self

    def attrs_batch(self, batch) -> "ProvQuery":
        """Per-probe attribute sets; must align 1:1 with ``.rows_batch``."""
        self._attrs, self._attrs_batched = batch, True
        return self

    # -- direction / endpoints -----------------------------------------------
    def forward(self) -> "ProvQuery":
        self._direction = "fwd"
        return self

    def backward(self) -> "ProvQuery":
        self._direction = "bwd"
        return self

    def to(self, dataset_id: str) -> "ProvQuery":
        """The answer dataset (index-qualified over a catalog)."""
        if dataset_id not in self._index.datasets:
            raise _unknown_dataset(self._index, dataset_id)
        self._target = dataset_id
        return self

    def how(self) -> "ProvQuery":
        """Also collect the per-op :class:`Hop` trace (Q5-Q8)."""
        self._how = True
        return self

    # -- non record/cells kinds ----------------------------------------------
    def transformations(self) -> "ProvQuery":
        """Q9: every transformation applied to ``.source`` (metadata only)."""
        self._kind = "transformations"
        return self

    def co_contributory(self, d2: str, via: Optional[str] = None) -> "ProvQuery":
        """Q10: records of ``d2`` used together with the probe rows to create
        new records (in ``via``; default — the per-probe last common
        descendant, matching the legacy free function)."""
        self._kind = "co_contributory"
        self._target = d2
        self._via = via
        return self

    def co_dependency(self, d1: str, d3: str) -> "ProvQuery":
        """Q11: records of ``d3`` lineage-dependent on the ``d1`` records
        that generated the probe rows."""
        self._kind = "co_dependency"
        self._anchor = d1
        self._target = d3
        return self

    # -- compile / execute -----------------------------------------------------
    def plan(self) -> QueryPlan:
        """Validate and compile to the immutable :class:`QueryPlan` IR."""
        if self._source is None:
            raise ValueError("missing .source(dataset)")
        kind = self._kind
        if kind is None:
            kind = "cells" if self._attrs is not None else "record"
        if kind == "transformations":
            return QueryPlan(kind=kind, source=self._source)

        ds = self._index.datasets[self._source]
        if self._rows is None:
            raise ValueError("missing .rows(...) / .rows_batch(...)")
        if self._rows_batched:
            rows = _batch_masks(self._rows, ds.n_rows, "rows")
        else:
            rows = _single_mask(self._rows, ds.n_rows, "rows")[None, :]
        B = rows.shape[0]

        attrs = None
        if self._attrs is not None:
            if self._attrs_batched:
                if not self._rows_batched:
                    raise ValueError(".attrs_batch(...) needs .rows_batch(...)")
                attrs = _batch_masks(self._attrs, ds.n_cols, "attrs")
            else:
                one = _single_mask(self._attrs, ds.n_cols, "attrs")
                attrs = np.broadcast_to(one, (B, ds.n_cols)).copy()
        elif kind == "cells":
            raise ValueError("cells plan needs .attrs(...)")

        if kind in ("record", "cells"):
            if self._direction is None:
                raise ValueError("missing .forward() / .backward()")
            if self._target is None:
                raise ValueError("missing .to(dataset)")

        return QueryPlan(
            kind=kind,
            source=self._source,
            target=self._target,
            direction=self._direction or "fwd",
            rows=rows,
            attrs=attrs,
            how=self._how,
            batched=self._rows_batched,
            via=self._via,
            anchor=self._anchor,
        )

    def run(self, session=None):
        """Execute through ``session`` (default: the shared session of the
        index or catalog this builder was opened over)."""
        if session is None:
            session = self._index.session()
        return session.run(self.plan())


def prov(index) -> ProvQuery:
    """Entry point: a fresh lazy builder over ``index`` — a
    :class:`~repro.core.pipeline.ProvenanceIndex` (bare dataset ids) or a
    :class:`~repro.provenance.catalog.ProvCatalog` (qualified refs)."""
    return ProvQuery(index)
