"""repro.provenance — the unified lazy query-plan API over a ProvenanceIndex.

The public surface is three names:

* :func:`prov` — fluent lazy builder,
  ``prov(index).source("D_l").rows([...]).forward().to(sink).run()``;
* :class:`QueryPlan` — the explicit IR a builder compiles to;
* :class:`QuerySession` — planner/executor; owns the hop-cache routing and
  fuses ``run_many`` batches that share endpoints into one packed pass.

The legacy Table-VII free functions (``repro.core.query.q1_forward`` …)
are thin deprecation shims over this package.
"""
from repro.provenance.builder import ProvQuery, prov
from repro.provenance.plan import AmbiguousProbeWarning, QueryPlan
from repro.provenance.session import QuerySession

__all__ = [
    "prov",
    "ProvQuery",
    "QueryPlan",
    "QuerySession",
    "AmbiguousProbeWarning",
]
