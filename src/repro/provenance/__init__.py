"""repro.provenance — the unified lazy query-plan API, single- and multi-index.

The single-index surface is three names:

* :func:`prov` — fluent lazy builder,
  ``prov(index).source("D_l").rows([...]).forward().to(sink).run()``;
* :class:`QueryPlan` — the explicit IR a builder compiles to;
* :class:`QuerySession` — planner/executor; owns the hop-cache routing and
  fuses ``run_many`` batches that share endpoints into one packed pass.

The **federated** surface generalizes it across pipeline boundaries without
merging index ownership:

* :class:`ProvCatalog` — named index registrations + :meth:`link
  <ProvCatalog.link>` declarations tying an output dataset of one index to
  a source dataset of another; ``prov(catalog)`` takes index-qualified refs
  (``"prep/raw_users"``);
* :class:`BoundaryHandle` — the read-only capability minted by
  ``ProvenanceIndex.export(dataset_id)``: probe-only access to the
  boundary's ancestors, :class:`CapabilityError` on anything else;
* :class:`FederatedSession` — ``catalog.session()``; same ``run`` /
  ``run_many`` / ``explain`` / ``stats`` surface as :class:`QuerySession`,
  splitting each plan at boundary datasets and stitching ``(B, n)`` mask
  stacks across link row alignments.

The **impact** surface (:mod:`repro.provenance.impact`) turns the same
closure machinery into deletion-propagation planning and what-if replay:
:func:`erasure_plan` emits a topologically ordered :class:`RecomputePlan`
(rebuild targets + stale hop-cache/cross-relation invalidations + cost
estimates), :func:`whatif_replay` re-executes only the provenance-related
sink rows under a source perturbation.

The legacy Table-VII free functions (``repro.core.query.q1_forward`` …)
are thin deprecation shims over this package.
"""
from repro.provenance.builder import ProvQuery, prov
from repro.provenance.catalog import (
    BoundaryHandle,
    CapabilityError,
    FederationError,
    Link,
    ProvCatalog,
)
from repro.provenance.federation import FederatedSession
from repro.provenance.impact import (
    CacheInvalidation,
    DatasetImpact,
    RecomputePlan,
    WhatIfResult,
    apply_invalidations,
    erasure_plan,
    whatif_replay,
)
from repro.provenance.plan import AmbiguousProbeWarning, QueryPlan
from repro.provenance.session import QuerySession
from repro.provenance.sharded import (
    ShardedComposedIndex,
    ShardedProvenanceIndex,
    ShardedTensor,
)

__all__ = [
    "prov",
    "ProvQuery",
    "QueryPlan",
    "QuerySession",
    "AmbiguousProbeWarning",
    "ProvCatalog",
    "BoundaryHandle",
    "FederatedSession",
    "Link",
    "CapabilityError",
    "FederationError",
    "RecomputePlan",
    "DatasetImpact",
    "CacheInvalidation",
    "WhatIfResult",
    "erasure_plan",
    "apply_invalidations",
    "whatif_replay",
    "ShardedProvenanceIndex",
    "ShardedComposedIndex",
    "ShardedTensor",
]
