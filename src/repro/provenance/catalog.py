"""The federated provenance catalog: named indexes, links, capabilities.

One :class:`~repro.core.pipeline.ProvenanceIndex` holds ONE pipeline's
provenance, but a deployment spans several — the data-prep pipeline's index
and the serving engine's index at minimum.  This module is the glue that
lets one query cross those ownership boundaries WITHOUT merging the indexes
or handing any party the other's mutable index object:

* :class:`ProvCatalog` — a registry of named members (full indexes or
  capability handles) plus :meth:`~ProvCatalog.link` declarations tying an
  output dataset of one member to a source dataset of another (optionally
  through a row **alignment**).  Dataset refs are *index-qualified* strings
  ``"name/dataset"``; ``prov(catalog)`` builds federated plans over them and
  :meth:`ProvCatalog.session` executes them
  (:class:`~repro.provenance.federation.FederatedSession`).
* :class:`BoundaryHandle` — a READ-ONLY capability minted by
  :meth:`ProvenanceIndex.export(dataset_id) <repro.core.pipeline.\
ProvenanceIndex.export>`.  It grants probe access to relations among the
  *ancestors* of the exported boundary dataset and nothing else: no
  ``record()`` / ``add_source()`` (they raise :class:`CapabilityError`), no
  resolution of non-ancestor datasets.  The ancestor set is fixed at export
  time — the op DAG is append-only with one producer per dataset, so no
  later write can grow a dataset's ancestry.
* typed errors — :class:`CapabilityError` for capability violations,
  :class:`FederationError` for malformed refs / links / unroutable plans.

Row alignment across a link: ``alignment[j]`` is the row of the *upstream*
boundary dataset that row ``j`` of the *downstream* source dataset came
from (``-1`` marks a downstream row with no upstream origin, e.g. an
injected request).  ``None`` means identity (row counts must match).
Forward mask stitching gathers ``down[:, j] = up[:, alignment[j]]``;
backward stitching OR-scatters (duplicate upstream rows accumulate).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Mapping, Optional, Tuple

import numpy as np

__all__ = [
    "ProvCatalog",
    "BoundaryHandle",
    "Link",
    "CapabilityError",
    "FederationError",
    "split_ref",
    "qualify",
]

QUALIFIER = "/"


class CapabilityError(PermissionError):
    """An operation the held capability does not grant (mutation through a
    :class:`BoundaryHandle`, or resolving a dataset outside its ancestor
    closure)."""


class FederationError(ValueError):
    """A malformed qualified ref / link declaration, or a plan the
    federation cannot route (e.g. cross-index attribute-level plans)."""


def split_ref(ref: str) -> Tuple[str, str]:
    """``"name/dataset"`` -> ``(name, dataset)``.  Splits on the FIRST
    qualifier so dataset ids may themselves contain ``/`` suffix parts."""
    if not isinstance(ref, str) or QUALIFIER not in ref:
        raise FederationError(
            f"expected an index-qualified dataset ref 'index/dataset', got "
            f"{ref!r}"
        )
    name, ds = ref.split(QUALIFIER, 1)
    if not name or not ds:
        raise FederationError(f"malformed qualified ref {ref!r}")
    return name, ds


def qualify(name: str, dataset_id: str) -> str:
    return f"{name}{QUALIFIER}{dataset_id}"


# ---------------------------------------------------------------------------
# Capability handle
# ---------------------------------------------------------------------------
class _AncestorView(Mapping):
    """Read-only view of an index's datasets restricted to an ancestor
    closure.  Membership tests outside the closure answer False (the
    capability does not even reveal existence); *resolving* a dataset that
    exists but lies outside the closure raises :class:`CapabilityError` so
    misuse is loud, not silently empty."""

    def __init__(self, index, allowed: frozenset) -> None:
        self._index = index
        self._allowed = allowed

    def __getitem__(self, dataset_id: str):
        if dataset_id in self._allowed:
            return self._index.datasets[dataset_id]
        if dataset_id in self._index.datasets:
            raise CapabilityError(
                f"dataset {dataset_id!r} is not an ancestor of the exported "
                "boundary; this BoundaryHandle cannot resolve it"
            )
        raise KeyError(dataset_id)

    def __contains__(self, dataset_id) -> bool:
        return dataset_id in self._allowed

    def __iter__(self) -> Iterator[str]:
        # index insertion order restricted to the closure (deterministic)
        return (d for d in self._index.datasets if d in self._allowed)

    def __len__(self) -> int:
        return len(self._allowed)


class BoundaryHandle:
    """A read-only probe capability over the ancestors of one exported
    dataset.  Minted by ``ProvenanceIndex.export(dataset_id)``; the exporting
    index keeps its ``ComposedIndex`` / ``QuerySession`` private and merely
    answers plans the handle has validated.

    The handle deliberately does NOT subclass or proxy ``ProvenanceIndex``:
    the only verbs it exposes are reads, and ``record`` / ``add_source``
    exist solely to raise :class:`CapabilityError`.
    """

    is_handle = True

    def __init__(self, index, boundary: str) -> None:
        if boundary not in index.datasets:
            raise KeyError(f"unknown dataset {boundary!r}")
        self.boundary = boundary
        self.index_name = index.name
        allowed = {boundary}
        for op in index.upstream_ops(boundary):
            allowed.add(op.output_id)
            allowed.update(op.input_ids)
        self._ancestors = frozenset(allowed)
        # name-mangled: the index object is the handle's private business
        self.__index = index

    # -- capability surface (reads) -----------------------------------------
    @property
    def datasets(self) -> Mapping:
        return _AncestorView(self.__index, self._ancestors)

    def path_exists(self, src: str, dst: str) -> bool:
        self._check_ref(src)
        self._check_ref(dst)
        return self.__index.path_exists(src, dst)

    def is_source_dataset(self, dataset_id: str) -> bool:
        """Whether ``dataset_id`` has no producer op (link-target check)."""
        self._check_ref(dataset_id)
        return dataset_id not in self.__index.producer

    def run(self, plan):
        self._check_plan(plan)
        return self.__index.session().run(plan)

    def run_many(self, plans) -> List:
        plans = list(plans)
        for p in plans:
            self._check_plan(p)
        return self.__index.session().run_many(plans)

    def run_masks(self, plan) -> np.ndarray:
        self._check_plan(plan)
        return self.__index.session().run_masks(plan)

    def run_record_terms(self, entry_masks, direction, collect_hops=False):
        """Multi-seed record propagation among the granted ancestors.

        Entries must be ancestors, and the returned masks (and hop traces)
        are FILTERED to the ancestor closure — a walk that escapes the
        capability's footprint reveals nothing about the rest of the index.
        """
        for ds in entry_masks:
            self._check_ref(ds)
        out = self.__index.session().run_record_terms(
            entry_masks, direction, collect_hops=collect_hops)
        masks, hops = out if collect_hops else (out, None)
        masks = {d: m for d, m in masks.items() if d in self._ancestors}
        if not collect_hops:
            return masks
        hops = [[h for h in trace
                 if h.src_dataset in self._ancestors
                 and h.dst_dataset in self._ancestors]
                for trace in hops]
        return masks, hops

    def run_attr_terms(self, entry_terms, direction, collect_hops=False):
        """Multi-seed attr-term propagation among the granted ancestors
        (same filtering contract as :meth:`run_record_terms`)."""
        for ds in entry_terms:
            self._check_ref(ds)
        out = self.__index.session().run_attr_terms(
            entry_terms, direction, collect_hops=collect_hops)
        terms, B = out[0], out[1]
        terms = {d: t for d, t in terms.items() if d in self._ancestors}
        if not collect_hops:
            return terms, B
        hops = [[h for h in trace
                 if h.src_dataset in self._ancestors
                 and h.dst_dataset in self._ancestors]
                for trace in out[2]]
        return terms, B, hops

    def relation_csr(self, src: str, dst: str):
        """The composed ``src``→``dst`` relation (scipy CSR) — the probe
        capability the export grants, in relation form; ancestors only."""
        self._check_ref(src)
        self._check_ref(dst)
        return self.__index.composed().relation_csr(src, dst)

    def relation_stats(self, src: str, dst: str):
        """``(RelStats | None, estimated one-time compose ns)`` for the
        composed ``src``→``dst`` relation — statistics only, no composition
        work (:meth:`repro.core.costmodel.CostModel.composed_estimate`).
        The cost-model read behind the federation's stitched-relation gate;
        ancestors only, like every other granted read."""
        self._check_ref(src)
        self._check_ref(dst)
        return self.__index.session().costmodel.composed_estimate(src, dst)

    def explain(self, plan) -> Dict[str, object]:
        self._check_plan(plan)
        return self.__index.session().explain(plan)

    def stats(self) -> Dict:
        return self.__index.session().stats()

    # -- denied verbs --------------------------------------------------------
    def record(self, *args, **kwargs):
        raise CapabilityError(
            "BoundaryHandle is read-only: record() is not granted "
            "(only the exporting index may register operations)"
        )

    def add_source(self, *args, **kwargs):
        raise CapabilityError(
            "BoundaryHandle is read-only: add_source() is not granted"
        )

    def export(self, dataset_id: str) -> "BoundaryHandle":
        """Attenuate: re-export any ancestor as a narrower handle."""
        self._check_ref(dataset_id)
        return BoundaryHandle(self.__index, dataset_id)

    # -- validation ----------------------------------------------------------
    def _check_ref(self, dataset_id: str) -> None:
        if dataset_id not in self._ancestors:
            raise CapabilityError(
                f"dataset {dataset_id!r} is not an ancestor of boundary "
                f"{self.boundary!r}; this BoundaryHandle cannot touch it"
            )

    def _check_plan(self, plan) -> None:
        for ref in plan.refs():
            self._check_ref(ref)

    def __repr__(self) -> str:
        return (f"BoundaryHandle({self.index_name}/{self.boundary}, "
                f"{len(self._ancestors)} ancestor datasets)")


class _IndexMember:
    """Full-access member adapter: the same surface as
    :class:`BoundaryHandle`, over an owned :class:`ProvenanceIndex`."""

    is_handle = False

    def __init__(self, index) -> None:
        self._index = index
        self.index_name = index.name

    @property
    def datasets(self):
        return self._index.datasets

    def path_exists(self, src: str, dst: str) -> bool:
        return self._index.path_exists(src, dst)

    def is_source_dataset(self, dataset_id: str) -> bool:
        return dataset_id not in self._index.producer

    def run(self, plan):
        return self._index.session().run(plan)

    def run_many(self, plans) -> List:
        return self._index.session().run_many(plans)

    def run_masks(self, plan) -> np.ndarray:
        return self._index.session().run_masks(plan)

    def run_record_terms(self, entry_masks, direction, collect_hops=False):
        return self._index.session().run_record_terms(
            entry_masks, direction, collect_hops=collect_hops)

    def run_attr_terms(self, entry_terms, direction, collect_hops=False):
        return self._index.session().run_attr_terms(
            entry_terms, direction, collect_hops=collect_hops)

    def relation_csr(self, src: str, dst: str):
        return self._index.composed().relation_csr(src, dst)

    def relation_stats(self, src: str, dst: str):
        return self._index.session().costmodel.composed_estimate(src, dst)

    def explain(self, plan) -> Dict[str, object]:
        return self._index.session().explain(plan)

    def stats(self) -> Dict:
        return self._index.session().stats()


# ---------------------------------------------------------------------------
# Links
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True, eq=False)
class Link:
    """One declared boundary: rows of ``down`` (a source dataset of the
    downstream member) are rows of ``up`` (any dataset of the upstream
    member), related by ``alignment`` (None = identity)."""

    up: str                             # qualified "prep/clean"
    down: str                           # qualified "serve/requests@0"
    alignment: Optional[np.ndarray]     # (n_down,) int64 into up rows; -1 = none

    def stitch_down(self, up_masks: np.ndarray, n_down: int) -> np.ndarray:
        """(B, n_up) upstream masks -> (B, n_down) downstream masks."""
        if self.alignment is None:
            return up_masks
        out = np.zeros((up_masks.shape[0], n_down), dtype=bool)
        sel = self.alignment >= 0
        if sel.any():
            out[:, sel] = up_masks[:, self.alignment[sel]]
        return out

    def stitch_up(self, down_masks: np.ndarray, n_up: int) -> np.ndarray:
        """(B, n_down) downstream masks -> (B, n_up) upstream masks.
        Duplicate alignments OR-accumulate (two requests over one upstream
        row both light it up)."""
        if self.alignment is None:
            return down_masks
        out = np.zeros((n_up, down_masks.shape[0]), dtype=bool)
        sel = self.alignment >= 0
        if sel.any():
            # ufunc.at accumulates over duplicate upstream rows, where plain
            # fancy-index assignment would keep only the last write
            np.logical_or.at(out, self.alignment[sel],
                             np.ascontiguousarray(down_masks[:, sel].T))
        return out.T

    def matrix(self, n_up: int, n_down: int):
        """The alignment as an ``(n_up, n_down)`` scipy-CSR selection
        matrix: ``A[alignment[j], j] = 1`` — so ``R_up @ A`` stitches a
        start→up relation down, and ``R_down @ A.T`` stitches back up
        (the relation-level twins of :meth:`stitch_down`/:meth:`stitch_up`,
        used by the federation's cross-index relation compose)."""
        import scipy.sparse as sp

        if self.alignment is None:
            return sp.identity(n_up, dtype=np.float32, format="csr")
        sel = np.flatnonzero(self.alignment >= 0)
        return sp.csr_matrix(
            (np.ones(len(sel), np.float32),
             (self.alignment[sel], sel)),
            shape=(n_up, n_down))


# ---------------------------------------------------------------------------
# The catalog
# ---------------------------------------------------------------------------
class _CatalogDatasets:
    """Qualified-ref resolution with the mapping protocol the fluent
    builder already speaks — ``prov(catalog).source("prep/raw")`` needs
    only ``in`` and ``[]``."""

    def __init__(self, catalog: "ProvCatalog") -> None:
        self._catalog = catalog

    def __contains__(self, ref) -> bool:
        try:
            member, ds = self._catalog.resolve(ref)
        except (FederationError, KeyError):
            return False
        return ds in member.datasets

    def __getitem__(self, ref: str):
        member, ds = self._catalog.resolve(ref)
        return member.datasets[ds]

    def __iter__(self) -> Iterator[str]:
        for name, member in self._catalog.members.items():
            for ds in member.datasets:
                yield qualify(name, ds)


class ProvCatalog:
    """Named provenance members + boundary links: the federation's schema.

    ::

        catalog = ProvCatalog()
        catalog.register("prep", prep_index)           # full access
        catalog.register("serve", serve_index)
        catalog.link("prep/clean", "serve/requests@0",
                     alignment=request_rows)           # rows into prep/clean

        prov(catalog).source("serve/responses@0").rows([2]) \\
            .backward().to("prep/raw").run()

    Members are either full :class:`ProvenanceIndex` objects or read-only
    :class:`BoundaryHandle` capabilities; queries route through each
    member's own shared ``QuerySession`` (cost-model planning, private
    hop-cache), so federation never merges or copies provenance.
    """

    def __init__(self, name: str = "catalog") -> None:
        self.name = name
        self.members: Dict[str, object] = {}      # name -> member adapter
        self.links: List[Link] = []
        self._session = None

    # -- registration ---------------------------------------------------------
    def register(self, name: str, owner) -> "ProvCatalog":
        """Register ``owner`` (a ``ProvenanceIndex`` or a
        :class:`BoundaryHandle`) under ``name``."""
        if QUALIFIER in name or not name:
            raise FederationError(
                f"member name {name!r} must be non-empty and contain no "
                f"{QUALIFIER!r}"
            )
        if name in self.members:
            raise FederationError(f"member {name!r} already registered")
        if isinstance(owner, BoundaryHandle):
            self.members[name] = owner
        elif hasattr(owner, "record") and hasattr(owner, "datasets"):
            self.members[name] = _IndexMember(owner)
        else:
            raise TypeError(
                f"cannot register {type(owner).__name__}: expected a "
                "ProvenanceIndex or a BoundaryHandle"
            )
        return self

    def member_of(self, index_or_handle) -> Optional[str]:
        """The registered name of ``index_or_handle``, if any."""
        for name, m in self.members.items():
            if m is index_or_handle or getattr(m, "_index", None) is index_or_handle:
                return name
        return None

    def resolve(self, ref: str):
        """``"name/dataset"`` -> ``(member, dataset_id)``."""
        name, ds = split_ref(ref)
        if name not in self.members:
            raise FederationError(
                f"unknown index {name!r} in ref {ref!r} "
                f"(registered: {sorted(self.members)})"
            )
        return self.members[name], ds

    @property
    def datasets(self) -> _CatalogDatasets:
        return _CatalogDatasets(self)

    # -- links ----------------------------------------------------------------
    def link(self, up_ref: str, down_ref: str,
             alignment=None) -> Link:
        """Declare that ``down_ref`` (a SOURCE dataset of its member — no
        producer op) holds rows drawn from ``up_ref`` in another member.
        ``alignment[j]`` is the ``up`` row behind ``down`` row ``j``
        (``-1`` = none); ``None`` means identity and requires equal row
        counts."""
        up_name, up_ds = split_ref(up_ref)
        down_name, down_ds = split_ref(down_ref)
        if up_name == down_name:
            raise FederationError(
                f"link endpoints must live in different members, both are "
                f"{up_name!r} (intra-index lineage is already an op)"
            )
        up_member, _ = self.resolve(up_ref)
        down_member, _ = self.resolve(down_ref)
        up_rec = up_member.datasets[up_ds]          # raises if not resolvable
        down_rec = down_member.datasets[down_ds]
        if not down_member.is_source_dataset(down_ds):
            raise FederationError(
                f"link target {down_ref!r} has a producer op in its own "
                "index; only source datasets can receive boundary rows"
            )
        if alignment is not None:
            alignment = np.asarray(alignment, dtype=np.int64)
            if alignment.shape != (down_rec.n_rows,):
                raise FederationError(
                    f"alignment has shape {alignment.shape}, link target "
                    f"{down_ref!r} has {down_rec.n_rows} rows"
                )
            if alignment.size and (alignment.max() >= up_rec.n_rows
                                   or alignment.min() < -1):
                raise FederationError(
                    f"alignment rows must be in [-1, {up_rec.n_rows}) for "
                    f"{up_ref!r}"
                )
            alignment = alignment.copy()
        elif up_rec.n_rows != down_rec.n_rows:
            raise FederationError(
                f"identity link needs equal row counts: {up_ref!r} has "
                f"{up_rec.n_rows}, {down_ref!r} has {down_rec.n_rows} "
                "(pass alignment=...)"
            )
        link = Link(up=up_ref, down=down_ref, alignment=alignment)
        self.links.append(link)
        return link

    # -- execution ------------------------------------------------------------
    def session(self, **kwargs):
        """The catalog's shared
        :class:`~repro.provenance.federation.FederatedSession` — same
        ``run`` / ``run_many`` / ``explain`` / ``stats`` surface as
        ``QuerySession``, plan-splitting across members."""
        from repro.provenance.federation import FederatedSession

        if self._session is None:
            self._session = FederatedSession(self, **kwargs)
        elif kwargs:
            raise ValueError("session() already configured; use catalog.session()")
        return self._session

    def stats(self) -> Dict:
        return self.session().stats()

    def __repr__(self) -> str:
        return (f"ProvCatalog({self.name!r}, members={sorted(self.members)}, "
                f"links={len(self.links)})")
