"""Impact analysis over recorded provenance: erasure planning and what-if
replay on ONE shared closure engine.

Three workloads, all driven by the same batched forward record walk
(:func:`repro.core.query.record_masks_terms_batch` per index, stitched
across :class:`~repro.provenance.catalog.Link` alignments per catalog):

* **Deletion propagation / GDPR erasure** — :func:`erasure_plan`: given
  rows of a source dataset (possibly an upstream member of a
  :class:`~repro.provenance.catalog.ProvCatalog`), compute the full
  downstream closure and emit a minimal, topologically ordered
  :class:`RecomputePlan`: which datasets the erasure touches and which
  must be rebuilt, which composed hop-cache entries / spill payloads /
  stitched cross-relations go stale, and an estimated rebuild cost from
  :mod:`repro.core.costmodel`.  The plan is a VALUE — nothing is dropped
  until :func:`apply_invalidations` executes its invalidation list.
* **What-if replay** — :func:`whatif_replay`: perturb source rows and
  re-execute ONLY the provenance-related downstream rows through
  :func:`repro.core.recompute.recompute_rows`, returning exact
  before/after values per affected sink row.  Contextual ops replay with
  their FITTED statistics (the §III-E recompute contract), so the deltas
  equal a full pipeline re-run exactly whenever the perturbation leaves
  fitted statistics unchanged — and rows outside the closure never move.
* **Federated attribute lineage** rides the same multi-seed walkers
  through :class:`~repro.provenance.federation.FederatedSession`
  (cross-index ``cells``/``how`` plans stitch attr-maps across links).

Every closure runs as ONE batched walk per member — never a per-row loop —
so erasure planning costs the same as a single lineage query.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import query as Q
from repro.core.costmodel import relation_probe_cost
from repro.core.pipeline import ProvenanceIndex
from repro.core.recompute import fetch_rows
from repro.dataprep.table import Table
from repro.provenance.catalog import (
    FederationError,
    ProvCatalog,
    qualify,
    split_ref,
)

__all__ = [
    "DatasetImpact",
    "CacheInvalidation",
    "RecomputePlan",
    "WhatIfResult",
    "erasure_plan",
    "apply_invalidations",
    "whatif_replay",
]


# ---------------------------------------------------------------------------
# Plan values
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DatasetImpact:
    """One dataset the erasure closure reaches."""

    ref: str                  # qualified "member/dataset" (bare over an index)
    rows: np.ndarray          # affected row ids, sorted ascending
    n_rows: int               # dataset row count
    materialized: bool        # §III-E policy keeps a stored table for it
    is_sink: bool
    est_ns: float = 0.0       # estimated provenance-guided rebuild cost

    @property
    def n_affected(self) -> int:
        return int(len(self.rows))


@dataclasses.dataclass(frozen=True)
class CacheInvalidation:
    """One cached derived artifact the erasure leaves stale.

    ``kind="composed"`` names a hop-cache entry of member/index ``scope``
    (``residency`` ``"ram"`` or ``"spilled"`` — spilled payloads are
    deleted from the on-disk store on apply); ``kind="cross"`` names a
    catalog-owned stitched cross-relation (``residency`` is the route
    mode, ``"fwd"``/``"bwd"``)."""

    scope: str                # member/index name (catalog name for "cross")
    kind: str                 # "composed" | "cross"
    src: str
    dst: str
    residency: str


@dataclasses.dataclass(frozen=True)
class RecomputePlan:
    """Minimal, topologically ordered erasure/rewrite plan.

    ``impacts`` lists every dataset the closure reaches, member-topological
    then registration (= dataflow) order, so executing deletions/rebuilds
    front-to-back never visits a dataset before its affected ancestors.
    ``invalidations`` lists every cached composed relation the rewrite
    poisons — nothing is dropped until :func:`apply_invalidations`."""

    sources: Tuple[Tuple[str, np.ndarray], ...]   # (ref, erased rows)
    impacts: Tuple[DatasetImpact, ...]
    invalidations: Tuple[CacheInvalidation, ...]
    est_total_ns: float

    @property
    def affected(self) -> Tuple[str, ...]:
        return tuple(i.ref for i in self.impacts)

    @property
    def rebuild(self) -> Tuple[str, ...]:
        """Materialized datasets that must be rebuilt, in execution order.
        The erasure sources themselves are excluded — their rows are
        deleted, not recomputed."""
        src_refs = {ref for ref, _ in self.sources}
        return tuple(i.ref for i in self.impacts
                     if i.materialized and i.ref not in src_refs)

    def impact(self, ref: str) -> Optional[DatasetImpact]:
        for i in self.impacts:
            if i.ref == ref:
                return i
        return None

    def describe(self) -> str:
        lines = ["RecomputePlan"]
        for ref, rows in self.sources:
            lines.append(f"  erase {ref}: {len(rows)} rows")
        for i in self.impacts:
            tag = " [rebuild]" if i.materialized and i.ref not in {
                r for r, _ in self.sources} else ""
            lines.append(f"  -> {i.ref}: {i.n_affected}/{i.n_rows} rows{tag}")
        for inv in self.invalidations:
            lines.append(f"  drop {inv.kind} {inv.scope}: "
                         f"{inv.src}->{inv.dst} ({inv.residency})")
        lines.append(f"  est rebuild cost ~{self.est_total_ns / 1e6:.2f} ms")
        return "\n".join(lines)


@dataclasses.dataclass(frozen=True)
class WhatIfResult:
    """Exact before/after values of the sink rows a perturbation reaches."""

    source: str
    sink: str
    source_rows: np.ndarray   # perturbed source rows (sorted, unique)
    sink_rows: np.ndarray     # provenance-related sink rows (sorted)
    before: Table             # sink_rows under the recorded run, aligned 1:1
    after: Table              # sink_rows under the perturbed replay

    @property
    def changed(self) -> np.ndarray:
        """(len(sink_rows),) bool — rows whose value or nullity moved."""
        d = (self.before.data != self.after.data) & ~(
            np.isnan(self.before.data) & np.isnan(self.after.data))
        return (d | (self.before.null != self.after.null)).any(axis=1)

    def row_deltas(self) -> List[Dict[str, Tuple[float, float]]]:
        """Per affected sink row, ``{column: (before, after)}`` for exactly
        the cells that changed (empty dict = row reached but unmoved)."""
        d = (self.before.data != self.after.data) & ~(
            np.isnan(self.before.data) & np.isnan(self.after.data))
        d |= self.before.null != self.after.null
        out: List[Dict[str, Tuple[float, float]]] = []
        for i in range(len(self.sink_rows)):
            out.append({
                self.before.columns[j]: (float(self.before.data[i, j]),
                                         float(self.after.data[i, j]))
                for j in np.flatnonzero(d[i])
            })
        return out


# ---------------------------------------------------------------------------
# Closure engine
# ---------------------------------------------------------------------------
def _as_rows(rows) -> np.ndarray:
    arr = np.unique(np.asarray(list(rows) if not isinstance(
        rows, np.ndarray) else rows, dtype=np.int64))
    return arr


def _seed_mask(rows: np.ndarray, n: int) -> np.ndarray:
    if rows.size and (rows.min() < 0 or rows.max() >= n):
        raise IndexError(f"rows out of range for dataset of {n} rows")
    mask = np.zeros((1, n), dtype=bool)
    mask[0, rows] = True
    return mask


def _closure_index(index: ProvenanceIndex, source: str, rows: np.ndarray
                   ) -> "OrderedDict[str, np.ndarray]":
    """Downstream closure within one index: dataset -> affected row ids,
    in registration (= topological) order.  ONE batched walk."""
    masks = Q.record_masks_terms_batch(
        index, {source: _seed_mask(rows, index.datasets[source].n_rows)},
        "fwd")
    out: "OrderedDict[str, np.ndarray]" = OrderedDict()
    for ds in index.datasets:
        m = masks.get(ds)
        if m is not None and m.any():
            out[ds] = np.flatnonzero(m[0])
    return out


def _member_topo(catalog: ProvCatalog) -> List[str]:
    """All members in link-topological order (Kahn over the link graph)."""
    indeg = {name: 0 for name in catalog.members}
    adj: Dict[str, List] = {}
    for link in catalog.links:
        up = split_ref(link.up)[0]
        adj.setdefault(up, []).append(link)
        indeg[split_ref(link.down)[0]] += 1
    order: List[str] = []
    ready = sorted(m for m, d in indeg.items() if d == 0)
    while ready:
        m = ready.pop(0)
        order.append(m)
        for link in adj.get(m, []):
            down = split_ref(link.down)[0]
            indeg[down] -= 1
            if indeg[down] == 0:
                ready.append(down)
    if len(order) != len(catalog.members):
        raise FederationError(
            "link graph has a cycle; impact closure needs an acyclic "
            "member graph")
    return order


def _closure_catalog(catalog: ProvCatalog, sources: Dict[str, np.ndarray]):
    """Downstream closure across the catalog.

    Returns ``(affected, member_seeds)``: affected maps qualified ref ->
    row ids in member-topological then per-member registration order;
    member_seeds maps member name -> {entry dataset: seed row count} (the
    cost model's probe anchors).  One batched multi-seed walk per member —
    a member reached through several links is walked ONCE, seeded with
    every stitched entry at the same time."""
    entries: Dict[str, Dict[str, np.ndarray]] = {}
    for ref, rows in sources.items():
        member_name, ds = split_ref(ref)
        if member_name not in catalog.members:
            raise FederationError(
                f"unknown index {member_name!r} in ref {ref!r} "
                f"(registered: {sorted(catalog.members)})")
        n = catalog.datasets[ref].n_rows
        ent = entries.setdefault(member_name, {})
        mask = _seed_mask(rows, n)
        ent[ds] = mask if ds not in ent else ent[ds] | mask
    out_links: Dict[str, List] = {}
    for link in catalog.links:
        out_links.setdefault(split_ref(link.up)[0], []).append(link)

    affected: "OrderedDict[str, np.ndarray]" = OrderedDict()
    member_seeds: Dict[str, Dict[str, int]] = {}
    for name in _member_topo(catalog):
        ent = {ds: m for ds, m in entries.get(name, {}).items() if m.any()}
        if not ent:
            continue
        member = catalog.members[name]
        member_seeds[name] = {ds: int(m.sum()) for ds, m in ent.items()}
        masks = member.run_record_terms(ent, "fwd")
        for ds in member.datasets:
            m = masks.get(ds)
            if m is not None and m.any():
                affected[qualify(name, ds)] = np.flatnonzero(m[0])
        for link in out_links.get(name, []):
            up_ds = split_ref(link.up)[1]
            m = masks.get(up_ds)
            if m is None or not m.any():
                continue
            down_name, down_ds = split_ref(link.down)
            stitched = link.stitch_down(
                m, catalog.datasets[link.down].n_rows)
            if stitched.any():
                d_ent = entries.setdefault(down_name, {})
                d_ent[down_ds] = stitched if down_ds not in d_ent \
                    else d_ent[down_ds] | stitched
    return affected, member_seeds


# ---------------------------------------------------------------------------
# Cache-invalidation enumeration
# ---------------------------------------------------------------------------
def _composed_invalidations(index: ProvenanceIndex, datasets, scope: str,
                            prefix: str = "") -> List[CacheInvalidation]:
    """Stale hop-cache entries of one index — enumeration only (the cache
    is read, never created: an index that was never probed has nothing to
    invalidate)."""
    composed = index._composed
    if composed is None:
        return []
    return [
        CacheInvalidation(scope, "composed", prefix + src, prefix + dst,
                          residency)
        for src, dst, residency in composed.stale_entries(datasets)
    ]


def _cross_invalidations(catalog: ProvCatalog,
                         affected_members) -> List[CacheInvalidation]:
    """Stale catalog-owned stitched cross-relations: an entry is stale
    when its endpoints OR any link it stitched through touch an affected
    member (a mid-route rewrite poisons the composed product even when
    both endpoints survive)."""
    store = getattr(catalog, "_cross_store", None)
    if store is None:
        return []
    out = []
    for (start, end, mode), (_rel, signature) in store.entries.items():
        touched = {split_ref(start)[0], split_ref(end)[0]}
        for up, down in signature:
            touched.add(split_ref(up)[0])
            touched.add(split_ref(down)[0])
        if touched & affected_members:
            out.append(CacheInvalidation(catalog.name, "cross", start, end,
                                         mode))
    return out


def apply_invalidations(target, plan: RecomputePlan) -> int:
    """Execute a plan's invalidation list: drop stale hop-cache entries
    (deleting spilled payloads) and stale stitched cross-relations.
    Returns how many artifacts were dropped.  Idempotent — re-applying a
    plan whose entries are already gone drops nothing.  BoundaryHandle
    members are read-only capabilities: their owners' caches are never
    touched (the plan carries no invalidations for them)."""
    dropped = 0
    by_scope: Dict[str, set] = {}
    cross = False
    for inv in plan.invalidations:
        if inv.kind == "cross":
            cross = True
        else:
            by_scope.setdefault(inv.scope, set())
    if isinstance(target, ProvCatalog):
        for name in by_scope:
            member = target.members.get(name)
            index = getattr(member, "_index", None)
            if index is None or index._composed is None:
                continue
            affected = [split_ref(i.ref)[1] for i in plan.impacts
                        if split_ref(i.ref)[0] == name]
            dropped += len(index._composed.invalidate_datasets(affected))
        if cross:
            store = getattr(target, "_cross_store", None)
            if store is not None:
                for inv in plan.invalidations:
                    if inv.kind == "cross" and (
                            inv.src, inv.dst, inv.residency) in store.entries:
                        store.drop((inv.src, inv.dst, inv.residency))
                        dropped += 1
    else:
        if target._composed is not None and by_scope:
            dropped += len(target._composed.invalidate_datasets(
                [i.ref for i in plan.impacts]))
    return dropped


# ---------------------------------------------------------------------------
# Erasure planning
# ---------------------------------------------------------------------------
def _estimate(member, seeds: Dict[str, int], ds: str,
              ) -> float:
    """Estimated cost of a provenance-guided rebuild of ``ds``'s affected
    rows from the nearest seed, via the member's cost model (compose the
    seed→ds relation once, probe it with the seed rows)."""
    for seed, n_rows in seeds.items():
        if seed == ds or not member.path_exists(seed, ds):
            continue
        try:
            rel, compose_ns = member.relation_stats(seed, ds)
        except Exception:
            return 0.0          # capability-filtered member: owner's concern
        if rel is not None:
            return float(compose_ns) + relation_probe_cost(rel, 1,
                                                           float(n_rows))
    return 0.0


def erasure_plan(target, source, rows) -> RecomputePlan:
    """Deletion-propagation plan for erasing ``rows`` of ``source``.

    ``target`` is a :class:`ProvenanceIndex` (``source`` a dataset id) or a
    :class:`ProvCatalog` (``source`` a qualified ``"member/dataset"`` ref —
    the closure crosses boundary links downstream).  The closure runs as
    one batched forward walk per index, so planning costs the same as a
    single lineage query regardless of how many rows are erased."""
    rows = _as_rows(rows)
    if isinstance(target, ProvCatalog):
        affected, member_seeds = _closure_catalog(target, {source: rows})
        impacts = []
        total = 0.0
        src_refs = {source}
        for ref, rws in affected.items():
            name, ds = split_ref(ref)
            rec = target.datasets[ref]
            est = 0.0
            if rec.materialized and ref not in src_refs:
                est = _estimate(target.members[name],
                                member_seeds.get(name, {}), ds)
            total += est
            impacts.append(DatasetImpact(
                ref=ref, rows=rws, n_rows=rec.n_rows,
                materialized=bool(rec.materialized),
                is_sink=bool(getattr(rec, "is_sink", False)), est_ns=est))
        affected_members = {split_ref(r)[0] for r in affected}
        invalidations: List[CacheInvalidation] = []
        for name in affected_members:
            index = getattr(target.members[name], "_index", None)
            if index is not None:
                local = [split_ref(r)[1] for r in affected
                         if split_ref(r)[0] == name]
                invalidations.extend(
                    _composed_invalidations(index, local, name))
        invalidations.extend(_cross_invalidations(target, affected_members))
        return RecomputePlan(
            sources=((source, rows),), impacts=tuple(impacts),
            invalidations=tuple(invalidations), est_total_ns=total)

    index: ProvenanceIndex = target
    if source not in index.datasets:
        raise KeyError(source)
    affected = _closure_index(index, source, rows)
    seeds = {source: int(len(rows))}
    impacts = []
    total = 0.0
    for ds, rws in affected.items():
        rec = index.datasets[ds]
        est = 0.0
        if rec.materialized and ds != source:
            session = index.session()
            rel, compose_ns = session.costmodel.composed_estimate(source, ds)
            if rel is not None:
                est = float(compose_ns) + relation_probe_cost(
                    rel, 1, float(len(rows)))
        total += est
        impacts.append(DatasetImpact(
            ref=ds, rows=rws, n_rows=rec.n_rows,
            materialized=rec.materialized, is_sink=rec.is_sink, est_ns=est))
    invalidations = tuple(_composed_invalidations(
        index, list(affected), index.name))
    return RecomputePlan(
        sources=((source, rows),), impacts=tuple(impacts),
        invalidations=invalidations, est_total_ns=total)


# ---------------------------------------------------------------------------
# What-if replay
# ---------------------------------------------------------------------------
def whatif_replay(target, source, rows, patch: Dict[str, Sequence],
                  sink: str) -> WhatIfResult:
    """Perturb ``rows`` of ``source`` (``patch`` maps column -> replacement
    values aligned with ``rows``) and replay ONLY the provenance-related
    rows of ``sink``, returning exact before/after values.

    The replay temporarily installs the patched source table and demotes
    every materialized dataset inside the closure, so
    :func:`~repro.core.recompute.recompute_rows` re-derives exactly the
    affected rows from the perturbed values; everything is restored on
    exit, recorded provenance untouched.  Contextual ops re-apply their
    FITTED statistics (the §III-E recompute contract): the result equals a
    full pipeline re-run whenever the perturbation leaves fitted
    statistics unchanged.

    Over a :class:`ProvCatalog`, ``source`` and ``sink`` must be qualified
    refs inside the SAME full-access member — value recomputation never
    leaves an index."""
    if isinstance(target, ProvCatalog):
        src_member, src_ds = split_ref(source)
        sink_member, sink_ds = split_ref(sink)
        if src_member != sink_member:
            raise FederationError(
                "what-if replay recomputes values, which never leave a "
                f"member: source is in {src_member!r}, sink in "
                f"{sink_member!r}")
        index = getattr(target.members[src_member], "_index", None)
        if index is None:
            raise FederationError(
                f"member {src_member!r} is a read-only boundary capability; "
                "what-if replay needs the full index")
        res = whatif_replay(index, src_ds, rows, patch, sink_ds)
        return dataclasses.replace(res, source=source, sink=sink)

    index = target
    rows = _as_rows(rows)
    rec = index.datasets[source]
    if not rec.materialized:
        raise ValueError(f"source {source!r} is not materialized")
    if sink not in index.datasets:
        raise KeyError(sink)

    closure = _closure_index(index, source, rows)
    sink_rows = closure.get(sink, np.empty(0, dtype=np.int64))
    before = fetch_rows(index, sink, sink_rows)

    patched = rec.table.copy()
    for col, vals in patch.items():
        j = patched.cid(col)
        vals = np.asarray(vals, dtype=np.float32)
        patched.data[rows, j] = vals
        patched.null[rows, j] = False

    demote = [ds for ds in closure
              if ds != source and index.datasets[ds].materialized]
    saved = [(rec, rec.table)]
    saved += [(index.datasets[d], index.datasets[d].table) for d in demote]
    rec.table = patched
    for d in demote:
        index.datasets[d].table = None
    try:
        after = fetch_rows(index, sink, sink_rows)
    finally:
        for r, t in saved:
            r.table = t
    return WhatIfResult(source=source, sink=sink, source_rows=rows,
                        sink_rows=sink_rows, before=before, after=after)
