"""QuerySession — the planner/executor behind the lazy builder.

A session owns the physical machinery for one :class:`ProvenanceIndex`:

* the **per-op vectorized walk** (:mod:`repro.core.query` — packed-bitset
  attr propagation, one ragged CSR gather per hop covering a whole probe
  batch);
* the **composed hop-cache** (:class:`repro.core.hopcache.ComposedIndex`,
  shared with every other session on the index via
  ``ProvenanceIndex.composed()``) whose relations now sum over *all*
  producer paths of the DAG, not just the unique chain;

and picks between them per :class:`QueryPlan`:

====================  ====================================================
plan shape            strategy
====================  ====================================================
``transformations``   metadata scan (no tensors touched)
``cells`` / ``how``   vectorized walk (attr bitplanes / hop traces live
                      on the per-op pass)
record-level          composed-relation probe when the relation is already
                      cached or the probe batch is large enough to amortize
                      composition (``hopcache_min_batch``); walk otherwise
====================  ====================================================

``run_many`` additionally **fuses** submitted plans that share a fuse key
(kind, direction, endpoints, via/anchor, how, attr-presence) into ONE packed
pass: the probe mask stacks concatenate along the batch axis, a single
physical execution answers the union, and results split back per plan.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import query as Q
from repro.provenance.plan import QueryPlan

__all__ = ["QuerySession"]


def _flatnonzeros(mask_stack: np.ndarray) -> List[np.ndarray]:
    return [np.flatnonzero(m) for m in mask_stack]


class QuerySession:
    """Planner + executor over one index; share one per serving tier."""

    def __init__(
        self,
        index,
        composed=None,
        *,
        use_hopcache: bool = True,
        hopcache_min_batch: int = 8,
    ) -> None:
        self.index = index
        self.composed = composed if composed is not None else index.composed()
        self.use_hopcache = use_hopcache
        self.hopcache_min_batch = int(hopcache_min_batch)
        self.counters: Dict[str, int] = {
            "plans": 0,
            "walk": 0,
            "hopcache": 0,
            "meta": 0,
            "fused_groups": 0,
            "fused_plans": 0,
        }

    # -- planning --------------------------------------------------------------
    def _strategy(self, plan: QueryPlan) -> str:
        if plan.kind == "transformations":
            return "meta"
        if plan.kind == "cells" or plan.how:
            return "walk"  # attr bitplanes / hop traces live on the walk
        if not self.use_hopcache:
            return "walk"
        if plan.kind == "record":
            pair = (
                (plan.source, plan.target)
                if plan.direction == "fwd"
                else (plan.target, plan.source)
            )
        elif plan.kind == "co_contributory":
            if plan.via is None:
                return "walk"  # per-probe via needs the walk's reach map
            pair = (plan.source, plan.via)
        else:  # co_dependency
            pair = (plan.anchor, plan.source)
        if self.composed.contains(*pair):
            return "hopcache"  # relation already composed: probe it
        if plan.n_probes >= self.hopcache_min_batch:
            return "hopcache"  # batch large enough to amortize composition
        return "walk"

    def explain(self, plan: QueryPlan) -> Dict[str, str]:
        """The planner's choice for ``plan``, without executing it."""
        return {"plan": plan.describe(), "strategy": self._strategy(plan)}

    # -- execution -------------------------------------------------------------
    def run(self, plan: QueryPlan):
        """Execute one plan.  Single-probe plans return legacy-shaped results
        (one index array / cell list / ``(recs, hops)``); batched plans
        return one such result per probe."""
        self.counters["plans"] += 1
        if plan.kind == "transformations":
            self.counters["meta"] += 1
            return self._exec_transformations(plan)
        per = self._execute(plan)
        return per if plan.batched else per[0]

    def run_many(self, plans: Sequence) -> List:
        """Execute a batch of plans, fusing same-fuse-key plans into one
        physical pass each.  Results come back in submission order."""
        plans = [p if isinstance(p, QueryPlan) else p.plan() for p in plans]
        results: List = [None] * len(plans)
        groups: Dict[tuple, List[int]] = {}
        for i, p in enumerate(plans):
            groups.setdefault(p.fuse_key(), []).append(i)
        for key, idxs in groups.items():
            if len(idxs) == 1 or key[0] == "transformations":
                for i in idxs:
                    results[i] = self.run(plans[i])
                continue
            sub = [plans[i] for i in idxs]
            fused = dataclasses.replace(
                sub[0],
                rows=np.concatenate([p.rows for p in sub], axis=0),
                attrs=(
                    np.concatenate([p.attrs for p in sub], axis=0)
                    if sub[0].attrs is not None
                    else None
                ),
                batched=True,
            )
            self.counters["plans"] += len(idxs)
            self.counters["fused_groups"] += 1
            self.counters["fused_plans"] += len(idxs)
            per = self._execute(fused)
            off = 0
            for i in idxs:
                p = plans[i]
                chunk = per[off : off + p.n_probes]
                off += p.n_probes
                results[i] = chunk if p.batched else chunk[0]
        return results

    # -- executors (each returns one payload per probe) -------------------------
    def _execute(self, plan: QueryPlan) -> List:
        strategy = self._strategy(plan)
        self.counters[strategy] += 1
        if plan.kind == "record":
            return self._exec_record(plan, strategy)
        if plan.kind == "cells":
            return self._exec_cells(plan)
        if plan.kind == "co_contributory":
            return self._exec_co_contributory(plan, strategy)
        if plan.kind == "co_dependency":
            return self._exec_co_dependency(plan, strategy)
        raise ValueError(f"unexpected plan kind {plan.kind!r}")

    def _exec_record(self, plan: QueryPlan, strategy: str) -> List:
        B = plan.n_probes
        if strategy == "hopcache":
            if plan.direction == "fwd":
                out = self.composed.probe_forward(plan.rows, plan.source, plan.target)
            else:
                out = self.composed.probe_backward(plan.rows, plan.source, plan.target)
            return _flatnonzeros(out)
        # walk
        walker = (
            Q.forward_record_masks_batch
            if plan.direction == "fwd"
            else Q.backward_record_masks_batch
        )
        if plan.how:
            masks, hops = walker(self.index, plan.source, plan.rows, collect_hops=True)
        else:
            masks, hops = walker(self.index, plan.source, plan.rows), None
        out = masks.get(
            plan.target,
            np.zeros((B, self.index.datasets[plan.target].n_rows), dtype=bool),
        )
        recs = _flatnonzeros(out)
        if plan.how:
            return list(zip(recs, hops))
        return recs

    def _exec_cells(self, plan: QueryPlan) -> List:
        B = plan.n_probes
        ds = self.index.datasets[plan.target]
        if plan.how:
            terms, _, hops = Q._attr_propagate_batch(
                self.index, plan.source, plan.rows, plan.attrs, plan.direction,
                collect_hops=True,
            )
        else:
            terms, _ = Q._attr_propagate_batch(
                self.index, plan.source, plan.rows, plan.attrs, plan.direction
            )
        cells = Q._cells_batch(terms.get(plan.target, []), B, ds.n_rows, ds.n_cols)
        if plan.how:
            return list(zip(cells, hops))
        return cells

    def _exec_co_contributory(self, plan: QueryPlan, strategy: str) -> List:
        d1, d2, via = plan.source, plan.target, plan.via
        if strategy == "hopcache":
            via_masks = self.composed.probe_forward(plan.rows, d1, via)
            back = self.composed.probe_backward(via_masks, via, d2)
            return _flatnonzeros(back)
        B = plan.n_probes
        fwd = Q.forward_record_masks_batch(self.index, d1, plan.rows)
        results: List[np.ndarray] = [np.zeros(0, dtype=np.int64)] * B
        groups: Dict[str, List[int]] = {}
        for b in range(B):
            v = via if via is not None else Q._pick_via(self.index, d1, d2, fwd, b)
            if v is None or v not in fwd or not fwd[v][b].any():
                continue
            groups.setdefault(v, []).append(b)
        for v, bs in groups.items():
            back = Q.backward_record_masks_batch(self.index, v, fwd[v][bs])
            if d2 not in back:
                continue
            for i, b in enumerate(bs):
                results[b] = np.flatnonzero(back[d2][i])
        return results

    def _exec_co_dependency(self, plan: QueryPlan, strategy: str) -> List:
        d2, d1, d3 = plan.source, plan.anchor, plan.target
        B = plan.n_probes
        if strategy == "hopcache":
            anc = self.composed.probe_backward(plan.rows, d2, d1)
            fwd = self.composed.probe_forward(anc, d1, d3)
            return _flatnonzeros(fwd)
        back = Q.backward_record_masks_batch(self.index, d2, plan.rows)
        empty = [np.zeros(0, dtype=np.int64)] * B
        if d1 not in back or not back[d1].any():
            return list(empty)
        fwd = Q.forward_record_masks_batch(self.index, d1, back[d1])
        if d3 not in fwd:
            return list(empty)
        return _flatnonzeros(fwd[d3])

    def _exec_transformations(self, plan: QueryPlan) -> List[Dict]:
        return [
            {
                "op_id": op.op_id,
                "op": op.info.op_name,
                "category": op.info.category.value,
                "contextual": op.info.contextual,
                "inputs": op.input_ids,
                "output": op.output_id,
            }
            for op in self.index.upstream_ops(plan.source)
        ]

    # -- introspection ----------------------------------------------------------
    def stats(self) -> Dict:
        """Planner counters + the shared hop-cache's counters
        (hits/misses/evictions/bytes) — assert on these to catch
        cache-routing regressions."""
        return {"planner": dict(self.counters), "hopcache": self.composed.stats()}
