"""QuerySession — the planner/executor behind the lazy builder.

A session owns the physical machinery for one :class:`ProvenanceIndex`:

* the **per-op vectorized walk** (:mod:`repro.core.query` — packed-bitset
  attr propagation, one ragged CSR gather per hop covering a whole probe
  batch);
* the **composed hop-cache** (:class:`repro.core.hopcache.ComposedIndex`,
  shared with every other session on the index via
  ``ProvenanceIndex.composed()``) whose relations now sum over *all*
  producer paths of the DAG, not just the unique chain;

and picks between them per :class:`QueryPlan`:

====================  ====================================================
plan shape            strategy
====================  ====================================================
``transformations``   metadata scan (no tensors touched)
``cells`` / ``how``   vectorized walk (attr bitplanes / hop traces live
                      on the per-op pass)
record-level          composed-relation probe when the relation is already
                      cached, or when the cost model estimates amortized
                      compose-then-probe under the walk; walk otherwise
====================  ====================================================

Record-level routing is driven by :class:`repro.core.costmodel.CostModel`
(shared with the hop-cache): per-pair chain statistics feed an estimated
walk cost (hops × batched gather) vs composition cost amortized over the
cumulative probe demand seen for the pair — so a stream of tiny probes to
one far pair flips to the hop-cache once demand accumulates.  The legacy
``hopcache_min_batch`` batch-size heuristic is DEPRECATED but still honored
when passed explicitly (with a ``DeprecationWarning``).

``run_many`` additionally **fuses** submitted plans that share a fuse key
(kind, direction, endpoints, via/anchor, how, attr-presence) into ONE packed
pass: the probe mask stacks concatenate along the batch axis, a single
physical execution answers the union, and results split back per plan.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import query as Q
from repro.provenance.plan import QueryPlan

__all__ = ["QuerySession", "run_many_fused"]


def _flatnonzeros(mask_stack: np.ndarray) -> List[np.ndarray]:
    return [np.flatnonzero(m) for m in mask_stack]


def run_many_fused(plans: Sequence, run_one, execute_batched,
                   counters: Dict[str, int]) -> List:
    """The shared ``run_many`` fusion contract: group plans by
    ``fuse_key()``, concatenate each group's probe mask stacks along the
    batch axis, execute ONE batched pass per group via ``execute_batched``
    (returns one payload per probe), and split results back in submission
    order.  Singleton groups and ``transformations`` plans fall back to
    ``run_one``.  Both :class:`QuerySession` and the catalog-level
    :class:`~repro.provenance.federation.FederatedSession` run on this, so
    fusion semantics cannot drift between the single-index and federated
    surfaces."""
    plans = [p if isinstance(p, QueryPlan) else p.plan() for p in plans]
    results: List = [None] * len(plans)
    groups: Dict[tuple, List[int]] = {}
    for i, p in enumerate(plans):
        groups.setdefault(p.fuse_key(), []).append(i)
    for key, idxs in groups.items():
        if len(idxs) == 1 or key[0] == "transformations":
            for i in idxs:
                results[i] = run_one(plans[i])
            continue
        sub = [plans[i] for i in idxs]
        fused = dataclasses.replace(
            sub[0],
            rows=np.concatenate([p.rows for p in sub], axis=0),
            attrs=(
                np.concatenate([p.attrs for p in sub], axis=0)
                if sub[0].attrs is not None
                else None
            ),
            batched=True,
        )
        counters["plans"] += len(idxs)
        counters["fused_groups"] += 1
        counters["fused_plans"] += len(idxs)
        per = execute_batched(fused)
        off = 0
        for i in idxs:
            p = plans[i]
            chunk = per[off : off + p.n_probes]
            off += p.n_probes
            results[i] = chunk if p.batched else chunk[0]
    return results


class QuerySession:
    """Planner + executor over one index; share one per serving tier."""

    def __init__(
        self,
        index,
        composed=None,
        *,
        use_hopcache: bool = True,
        fused_walk: Optional[bool] = None,
        hopcache_min_batch: Optional[int] = None,
    ) -> None:
        self.index = index
        self.composed = composed if composed is not None else index.composed()
        self.use_hopcache = use_hopcache
        # tri-state like use_pallas: None -> fused kernel walk iff on TPU
        # (keeps host routing bit-for-bit and numpy-only paths jax-free);
        # True forces it everywhere (the parity-test path), False disables
        self.fused_walk = fused_walk
        if hopcache_min_batch is not None:
            warnings.warn(
                "hopcache_min_batch is deprecated: the QuerySession now "
                "routes record-level plans with a cost model (see "
                "repro.core.costmodel); passing hopcache_min_batch keeps the "
                "legacy batch-size heuristic for this session.",
                DeprecationWarning,
                stacklevel=2,
            )
            hopcache_min_batch = int(hopcache_min_batch)
        self.hopcache_min_batch = hopcache_min_batch
        # shared with the hop-cache so chain statistics are computed once
        self.costmodel = self.composed.costmodel
        self.counters: Dict[str, int] = {
            "plans": 0,
            "walk": 0,
            "hopcache": 0,
            "fused_walk": 0,
            "meta": 0,
            "fused_groups": 0,
            "fused_plans": 0,
        }

    # -- planning --------------------------------------------------------------
    def _plan_pairs(self, plan: QueryPlan) -> Optional[List[Tuple[str, str]]]:
        """EVERY (upstream, downstream) relation the hop-cache strategy would
        probe for this plan — two legs for the co-queries, matching their
        executors — or None when the plan cannot route through the
        hop-cache."""
        if plan.kind == "record":
            return [
                (plan.source, plan.target)
                if plan.direction == "fwd"
                else (plan.target, plan.source)
            ]
        if plan.kind == "co_contributory":
            if plan.via is None:
                return None  # per-probe via needs the walk's reach map
            return [(plan.source, plan.via), (plan.target, plan.via)]
        # co_dependency: back-probe (d1, d2) then forward-probe (d1, d3)
        return [(plan.anchor, plan.source), (plan.anchor, plan.target)]

    def _choose(self, plan: QueryPlan, note: bool) -> Optional[Dict[str, object]]:
        """The cost model's verdict for a cost-routable plan — summed over
        every relation leg the hopcache strategy would compose (pricing only
        one leg of a co-query would compare the walk against half the real
        cost) — or None when the decision never reaches the cost model."""
        if self.hopcache_min_batch is not None:
            return None
        pairs = self._plan_pairs(plan)
        if pairs is None:
            return None
        uncached = [p for p in pairs if not self.composed.contains(*p)]
        if not uncached:
            return None  # every leg already composed: contains-path decides
        probe_rows = (float(plan.rows.sum()) / max(plan.n_probes, 1)
                      if plan.rows is not None else 1.0)
        legs = [
            self.costmodel.choose(
                p[0], p[1], plan.n_probes, probe_rows, note=note,
                budget_bytes=self.composed.memory_budget_bytes)
            for p in uncached
        ]
        walk = sum(leg["walk_ns"] for leg in legs)
        hopcache = sum(leg["hopcache_ns"] for leg in legs)
        return {
            "strategy": "hopcache" if hopcache < walk else "walk",
            "walk_ns": walk,
            "hopcache_ns": hopcache,
            "compose_ns": sum(leg["compose_ns"] for leg in legs),
            "demand": min(leg["demand"] for leg in legs),
            "retainable": all(leg["retainable"] for leg in legs),
            # every leg estimated implicit: the hop-cache would hold this
            # plan's relations as gather arrays, not CSRs/bitplanes
            "structured": all(bool(leg.get("structured")) for leg in legs),
            "legs": legs if len(legs) > 1 else None,
        }

    def _strategy(self, plan: QueryPlan, note: bool = True) -> str:
        if plan.kind == "transformations":
            return "meta"
        if plan.kind == "cells" or plan.how:
            return "walk"  # attr bitplanes / hop traces live on the walk
        if not self.use_hopcache:
            return "walk"
        pairs = self._plan_pairs(plan)
        if pairs is None:
            return "walk"
        if all(self.composed.contains(*p) for p in pairs):
            return "hopcache"  # relations already composed: probe them
        if self.hopcache_min_batch is not None:  # deprecated legacy heuristic
            return ("hopcache" if plan.n_probes >= self.hopcache_min_batch
                    else "walk")
        return self._choose(plan, note)["strategy"]

    def explain(self, plan: QueryPlan) -> Dict[str, object]:
        """The planner's choice for ``plan``, without executing it (and
        without advancing the cost model's per-pair demand counters).
        Includes the cost model's estimates when they decided the routing.
        """
        out: Dict[str, object] = {"plan": plan.describe()}
        cost = None
        if plan.kind not in ("transformations", "cells") and not plan.how \
                and self.use_hopcache:
            cost = self._choose(plan, note=False)
        if cost is not None:
            out["strategy"] = cost["strategy"]
            out["cost"] = cost
        else:
            out["strategy"] = self._strategy(plan, note=False)
        pairs = None
        if plan.kind not in ("transformations", "cells"):
            pairs = self._plan_pairs(plan)
        if pairs is not None:
            # spill-tier residency per relation leg: "ram" / "spilled" (one
            # mmap fault on first probe) / "uncomposed" — no LRU touch
            out["residency"] = [
                {"pair": p, "state": self.composed.residency(*p)
                 or "uncomposed"}
                for p in pairs
            ]
        # where the routing constants came from (default vs calibration file)
        from repro.core.costmodel import constants_provenance

        out["constants"] = constants_provenance()
        return out

    def _fused_walk_on(self) -> bool:
        """Resolve the tri-state ``fused_walk`` flag; the None default means
        "fused kernel iff on TPU" and never imports jax on hosts."""
        if self.fused_walk is not None:
            return bool(self.fused_walk)
        import sys

        if "jax" not in sys.modules:
            return False
        from repro.kernels import ops as K

        return K.on_tpu()

    # -- execution -------------------------------------------------------------
    def run(self, plan: QueryPlan):
        """Execute one plan.  Single-probe plans return legacy-shaped results
        (one index array / cell list / ``(recs, hops)``); batched plans
        return one such result per probe."""
        self.counters["plans"] += 1
        if plan.kind == "transformations":
            self.counters["meta"] += 1
            return self._exec_transformations(plan)
        per = self._execute(plan)
        return per if plan.batched else per[0]

    def run_many(self, plans: Sequence) -> List:
        """Execute a batch of plans, fusing same-fuse-key plans into one
        physical pass each.  Results come back in submission order."""
        return run_many_fused(plans, self.run, self._execute, self.counters)

    def run_masks(self, plan: QueryPlan) -> np.ndarray:
        """Execute a plain record-level plan and return the RAW ``(B,
        n_target)`` boolean mask stack — no per-probe index conversion.

        The federation's per-segment entry point: intermediate segment
        results feed straight into the next boundary stitch, so
        materializing index arrays per probe would be pure overhead.
        Routing and counters are identical to :meth:`run` (they share
        :meth:`_record_masks`, the one record executor).
        """
        if plan.kind != "record" or plan.how:
            raise ValueError("run_masks handles plain record plans only")
        self.counters["plans"] += 1
        strategy = self._strategy(plan)
        self.counters[strategy] += 1
        return self._record_masks(plan, strategy)

    def run_record_terms(self, entry_masks: Dict[str, np.ndarray],
                         direction: str, collect_hops: bool = False):
        """Record propagation from MULTIPLE seed datasets in one pass.

        The federation's how-provenance segment hook: ``entry_masks`` maps
        dataset id -> ``(B, n_rows)`` bool probe stacks, and the return is
        the full reachable ``{dataset: (B, n) bool}`` dict (plus per-probe
        :class:`~repro.core.query.Hop` traces with ``collect_hops``).
        Seeding every boundary entry at once keeps the hop trace identical
        to a merged index's single walk — per entry/exit passes would
        re-record shared ops.  Always walks (hop traces live on the
        per-op pass).
        """
        self.counters["plans"] += 1
        self.counters["walk"] += 1
        return Q.record_masks_terms_batch(self.index, entry_masks, direction,
                                          collect_hops=collect_hops)

    def run_attr_terms(self, entry_terms, direction: str,
                       collect_hops: bool = False):
        """Attr-term propagation from MULTIPLE seed datasets in one pass.

        The federation's cells/how segment hook (the attribute-level
        analogue of :meth:`run_masks`): ``entry_terms`` maps dataset id ->
        lists of ``((B, n_rows) bool, (B, nw) uint32)`` packed terms, and
        the return is the full reachable terms dict plus per-probe hop
        traces (``(terms, B, hops)`` with ``collect_hops``, else
        ``(terms, B)``).  Attr bitplanes live on the per-op walk, so this
        never routes through the hop-cache.
        """
        self.counters["plans"] += 1
        self.counters["walk"] += 1
        return Q.attr_propagate_terms_batch(self.index, entry_terms,
                                            direction,
                                            collect_hops=collect_hops)

    # -- executors (each returns one payload per probe) -------------------------
    def _execute(self, plan: QueryPlan) -> List:
        strategy = self._strategy(plan)
        self.counters[strategy] += 1
        if plan.kind == "record":
            return self._exec_record(plan, strategy)
        if plan.kind == "cells":
            return self._exec_cells(plan)
        if plan.kind == "co_contributory":
            return self._exec_co_contributory(plan, strategy)
        if plan.kind == "co_dependency":
            return self._exec_co_dependency(plan, strategy)
        raise ValueError(f"unexpected plan kind {plan.kind!r}")

    def _record_masks(self, plan: QueryPlan, strategy: str) -> np.ndarray:
        """The one plain-record executor: (B, n_target) bool per strategy.
        Both :meth:`run` (via ``_exec_record``) and :meth:`run_masks` (the
        federation's segment hook) answer through this, so routing and
        fallback shapes cannot diverge between the two surfaces."""
        if strategy == "hopcache":
            if plan.direction == "fwd":
                return self.composed.probe_forward(
                    plan.rows, plan.source, plan.target)
            return self.composed.probe_backward(
                plan.rows, plan.source, plan.target)
        if self._fused_walk_on():
            fused = Q.fused_walk_record_masks_batch(
                self.index, plan.source, plan.target, plan.rows,
                plan.direction,
            )
            if fused is not None:  # non-linear chains fall through to the walk
                self.counters["fused_walk"] += 1
                return fused
        walker = (
            Q.forward_record_masks_batch
            if plan.direction == "fwd"
            else Q.backward_record_masks_batch
        )
        masks = walker(self.index, plan.source, plan.rows)
        return masks.get(
            plan.target,
            np.zeros((plan.n_probes, self.index.datasets[plan.target].n_rows),
                     dtype=bool),
        )

    def _exec_record(self, plan: QueryPlan, strategy: str) -> List:
        if not plan.how:
            return _flatnonzeros(self._record_masks(plan, strategy))
        # how-traces only live on the walk (see _strategy)
        walker = (
            Q.forward_record_masks_batch
            if plan.direction == "fwd"
            else Q.backward_record_masks_batch
        )
        masks, hops = walker(self.index, plan.source, plan.rows,
                             collect_hops=True)
        out = masks.get(
            plan.target,
            np.zeros((plan.n_probes, self.index.datasets[plan.target].n_rows),
                     dtype=bool),
        )
        return list(zip(_flatnonzeros(out), hops))

    def _exec_cells(self, plan: QueryPlan) -> List:
        B = plan.n_probes
        ds = self.index.datasets[plan.target]
        if plan.how:
            terms, _, hops = Q._attr_propagate_batch(
                self.index, plan.source, plan.rows, plan.attrs, plan.direction,
                collect_hops=True,
            )
        else:
            terms, _ = Q._attr_propagate_batch(
                self.index, plan.source, plan.rows, plan.attrs, plan.direction
            )
        cells = Q._cells_batch(terms.get(plan.target, []), B, ds.n_rows, ds.n_cols)
        if plan.how:
            return list(zip(cells, hops))
        return cells

    def _exec_co_contributory(self, plan: QueryPlan, strategy: str) -> List:
        d1, d2, via = plan.source, plan.target, plan.via
        if strategy == "hopcache":
            via_masks = self.composed.probe_forward(plan.rows, d1, via)
            back = self.composed.probe_backward(via_masks, via, d2)
            return _flatnonzeros(back)
        B = plan.n_probes
        fwd = Q.forward_record_masks_batch(self.index, d1, plan.rows)
        results: List[np.ndarray] = [np.zeros(0, dtype=np.int64)] * B
        groups: Dict[str, List[int]] = {}
        for b in range(B):
            v = via if via is not None else Q._pick_via(self.index, d1, d2, fwd, b)
            if v is None or v not in fwd or not fwd[v][b].any():
                continue
            groups.setdefault(v, []).append(b)
        for v, bs in groups.items():
            back = Q.backward_record_masks_batch(self.index, v, fwd[v][bs])
            if d2 not in back:
                continue
            for i, b in enumerate(bs):
                results[b] = np.flatnonzero(back[d2][i])
        return results

    def _exec_co_dependency(self, plan: QueryPlan, strategy: str) -> List:
        d2, d1, d3 = plan.source, plan.anchor, plan.target
        B = plan.n_probes
        if strategy == "hopcache":
            anc = self.composed.probe_backward(plan.rows, d2, d1)
            fwd = self.composed.probe_forward(anc, d1, d3)
            return _flatnonzeros(fwd)
        back = Q.backward_record_masks_batch(self.index, d2, plan.rows)
        empty = [np.zeros(0, dtype=np.int64)] * B
        if d1 not in back or not back[d1].any():
            return list(empty)
        fwd = Q.forward_record_masks_batch(self.index, d1, back[d1])
        if d3 not in fwd:
            return list(empty)
        return _flatnonzeros(fwd[d3])

    def _exec_transformations(self, plan: QueryPlan) -> List[Dict]:
        return [
            {
                "op_id": op.op_id,
                "op": op.info.op_name,
                "category": op.info.category.value,
                "contextual": op.info.contextual,
                "inputs": op.input_ids,
                "output": op.output_id,
            }
            for op in self.index.upstream_ops(plan.source)
        ]

    # -- introspection ----------------------------------------------------------
    def stats(self) -> Dict:
        """Planner counters + the shared hop-cache's counters
        (hits/misses/evictions/bytes) — assert on these to catch
        cache-routing regressions.  ``index`` names the owning index so a
        federation can aggregate per-index stats attributably."""
        return {"index": self.index.name, "planner": dict(self.counters),
                "hopcache": self.composed.stats()}
