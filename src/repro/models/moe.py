"""Mixture-of-Experts FFN with GROUPED sort-based token dispatch.

Expert-parallel design (qwen3-moe 128e/top-8, dbrx 16e/top-4, jamba 16e/top-2):

* router: (T, E) logits -> top-k expert ids + softmaxed weights;
* dispatch: the token stream is reshaped into G groups (the launcher sets
  G = |data shards| via the pshard policy, so each group IS one data shard's
  tokens).  Tokens are replicated k times and SORTED BY EXPERT WITHIN THEIR
  GROUP — argsort along the last axis keeps the G axis sharded, so the sort
  is LOCAL to each data shard.  (A single global sort forces GSPMD to
  all-gather the whole token stream: the baseline dry-run measured that at
  933 s of collective time per step on qwen3-moe train_4k — the grouped
  dispatch is the fix, see EXPERIMENTS.md §Perf.)
* capacity: rank-within-expert computed per group; overflow drops
  (capacity_factor bounded, lane-aligned);
* expert compute: (G, E, C, D) x (E, D, F) einsums — G sharded over data,
  E sharded over "model" (expert parallel).  The buffer is built locally
  per (data, expert) shard pair; the only EP collective left is the
  combine-side gather of expert outputs.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import pshard as PS

__all__ = ["init_moe", "moe_forward"]

Params = Dict[str, jax.Array]


def init_moe(cfg: ModelConfig, key: jax.Array) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) / math.sqrt(d),
        "wi": jax.random.normal(ks[1], (e, d, f), jnp.float32) / math.sqrt(d),
        "wg": jax.random.normal(ks[2], (e, d, f), jnp.float32) / math.sqrt(d),
        "wo": jax.random.normal(ks[3], (e, f, d), jnp.float32) / math.sqrt(f),
    }


def _capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    # C is a SUBLANE dim of the (G,E,C,D) buffer (D covers the 128 lanes), so
    # 8-alignment suffices; a 128 floor padded decode-sized batches 16x
    # (measured: qwen3-moe decode_32k useful ratio 0.078 with floor 128).
    per = tokens_per_group * cfg.top_k / cfg.n_experts
    c = int(math.ceil(per * cfg.capacity_factor / 8.0)) * 8
    return max(c, 8)


def _n_groups(t: int) -> int:
    pol = PS.policy() or {}
    g = int(pol.get("moe_groups", 1) or 1)
    return g if (g > 1 and t % g == 0) else 1


def moe_forward(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    """x: (B, S, D) -> (B, S, D)."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    g = _n_groups(t)
    tg = t // g
    xt = x.reshape(g, tg, d)
    xt = PS.hint(xt, "dp", None, None)
    dt = x.dtype

    # ---- router ---------------------------------------------------------
    logits = (xt.astype(jnp.float32) @ p["router"])            # (G, Tg, E)
    topw, topi = jax.lax.top_k(logits, k)                      # (G, Tg, k)
    topw = jax.nn.softmax(topw, axis=-1).astype(dt)

    # ---- grouped sort-based dispatch --------------------------------------
    flat_e = topi.reshape(g, tg * k)                           # expert per slot
    flat_w = topw.reshape(g, tg * k)
    flat_tok = jnp.tile(
        jnp.repeat(jnp.arange(tg, dtype=jnp.int32), k)[None, :], (g, 1))

    order = jnp.argsort(flat_e, axis=-1)                       # LOCAL per group
    e_sorted = jnp.take_along_axis(flat_e, order, axis=-1)
    tok_sorted = jnp.take_along_axis(flat_tok, order, axis=-1)

    # rank within expert = position - first position of that expert (per group)
    eids = jnp.arange(e, dtype=e_sorted.dtype)
    starts = jax.vmap(jnp.searchsorted)(e_sorted, jnp.tile(eids[None], (g, 1)))
    rank = (jnp.arange(tg * k, dtype=jnp.int32)[None, :]
            - jnp.take_along_axis(starts, e_sorted, axis=-1).astype(jnp.int32))

    cap = _capacity(cfg, tg)
    keep = rank < cap                                          # overflow drops
    slot = jnp.where(keep, e_sorted * cap + rank, e * cap)     # sentinel row
    gidx = jnp.tile(jnp.arange(g, dtype=jnp.int32)[:, None], (1, tg * k))

    # slot -> token map (SMALL int array: (G, E*C+1), replicated over 'model')
    tok_of_slot = jnp.full((g, e * cap + 1), tg, jnp.int32)    # default: zero row
    tok_of_slot = tok_of_slot.at[gidx, slot].set(tok_sorted, mode="drop")

    # dispatch is a GATHER from the token stream, not a scatter into the
    # buffer: tokens are dp-sharded / tp-replicated, so every expert shard
    # gathers its own (E/|tp|, C) rows LOCALLY.  (A scatter here makes GSPMD
    # replicate the (G,E,C,D) buffer across 'model' — measured 17 GB of
    # all-gather per microbatch-layer on qwen3-moe before this change.)
    xt_pad = jnp.concatenate([xt, jnp.zeros((g, 1, d), dt)], axis=1)
    buf = jnp.take_along_axis(
        xt_pad, tok_of_slot[:, : e * cap, None], axis=1
    ).reshape(g, e, cap, d)                                    # (G, E, C, D)
    buf = PS.hint(buf, "dp", "tp", None, None)                 # expert-parallel

    # ---- expert compute (E sharded over "model", G over data) -------------
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["wg"].astype(dt)))
    h = h * jnp.einsum("gecd,edf->gecf", buf, p["wi"].astype(dt))
    y = jnp.einsum("gecf,efd->gecd", h, p["wo"].astype(dt))    # (G, E, C, D)

    # ---- combine: SCATTER-ADD from buffer rows, not gather ------------------
    # Gathering y_flat[slot] would need every expert's rows on every data
    # shard — GSPMD lowers that as a full all-gather of the (G,E,C,D) buffer
    # over the model axis (measured: 2.7 GB/layer/microbatch on qwen3-moe).
    # Instead each BUFFER ROW knows its destination token (slot->token map,
    # small and replicated) and its router weight; every expert shard
    # scatter-ADDS only the rows it owns into the (G,Tg,D) token layout, and
    # the partial sums meet in one all-reduce of token activations — k/E of
    # the buffer bytes.
    w_sorted = jnp.take_along_axis(flat_w, order, axis=-1)     # (G, Tg*k)
    w_of_slot = jnp.zeros((g, e * cap + 1), dt)
    w_of_slot = w_of_slot.at[gidx, slot].set(w_sorted.astype(dt), mode="drop")

    y_flat = y.reshape(g, e * cap, d)                          # (dp, tp)-sharded
    contrib = y_flat * w_of_slot[:, : e * cap, None]           # elementwise
    rows = jnp.tile(jnp.arange(g, dtype=jnp.int32)[:, None], (1, e * cap))
    out = jnp.zeros((g, tg + 1, d), dt)
    out = out.at[rows, tok_of_slot[:, : e * cap]].add(contrib, mode="drop")
    out = PS.hint(out[:, :tg], "dp", None, None)
    return out.reshape(b, s, d)
