"""Mamba2 block: short causal conv + SSD (state-space duality) mixer.

Training path is the CHUNKED dual form (arXiv:2405.21060 §6): within-chunk
terms are attention-like einsums (MXU-friendly), across-chunk terms are a
scan over per-chunk states — O(S) memory, matmul-dominated compute.

Decode path is the recurrent form: a constant-size (B, H, P, N) state and a
(B, k-1, conv_dim) conv ring — no KV cache, which is why mamba2/jamba RUN
the long_500k cell (DESIGN.md §6).

Shapes: D=d_model, d_inner=expand*D, P=ssm_head_dim, H=d_inner/P heads,
N=ssm_state, G=1 B/C group.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

__all__ = ["init_ssd", "ssd_forward", "ssd_decode", "init_ssd_cache"]

Params = Dict[str, jax.Array]


def _dims(cfg: ModelConfig):
    d = cfg.d_model
    di = cfg.d_inner
    p_ = cfg.ssm_head_dim
    h = di // p_
    n = cfg.ssm_state
    return d, di, p_, h, n


def init_ssd(cfg: ModelConfig, key: jax.Array) -> Params:
    d, di, p_, h, n = _dims(cfg)
    conv_dim = di + 2 * n
    ks = jax.random.split(key, 4)
    return {
        # in_proj -> [z (di), x (di), B (n), C (n), dt (h)]
        "in_proj": jax.random.normal(ks[0], (d, 2 * di + 2 * n + h), jnp.float32) / math.sqrt(d),
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim), jnp.float32) * 0.1,
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.zeros((h,), jnp.float32),           # A = -exp(A_log) in (-1, 0]
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm_scale": jnp.zeros((di,), jnp.float32),
        "out_proj": jax.random.normal(ks[3], (di, d), jnp.float32) / math.sqrt(di),
    }


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    _, di, p_, h, n = _dims(cfg)
    z = proj[..., :di]
    xbc = proj[..., di : di + di + 2 * n]
    dt = proj[..., di + di + 2 * n :]
    return z, xbc, dt


def _causal_conv(w: jax.Array, b: jax.Array, x: jax.Array) -> jax.Array:
    """Depthwise causal conv, kernel (k, C), x (B, S, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    return jax.nn.silu(out + b[None, None, :])


def _gated_norm(x: jax.Array, z: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    x = x * jax.nn.silu(z)
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
            * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def ssd_forward(cfg: ModelConfig, p: Params, x: jax.Array,
                return_cache: bool = False):
    """Chunked SSD training/prefill pass.  x: (B, S, D) -> (B, S, D).

    ``return_cache=True`` additionally returns the decode-handoff cache:
    the final recurrent state and the conv ring tail."""
    d, di, p_, h, n = _dims(cfg)
    b, s, _ = x.shape
    q = cfg.ssm_chunk
    assert s % q == 0, (s, q)
    c = s // q
    dt_ = x.dtype

    proj = x @ p["in_proj"].astype(dt_)
    z, xbc_raw, dt = _split_proj(cfg, proj)
    xbc = _causal_conv(p["conv_w"].astype(dt_), p["conv_b"].astype(dt_), xbc_raw)
    xs = xbc[..., :di].reshape(b, s, h, p_)
    Bm = xbc[..., di : di + n]                                   # (B,S,N) G=1
    Cm = xbc[..., di + n :]                                      # (B,S,N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])                                     # (H,)
    dA = dt * A[None, None, :]                                   # (B,S,H)

    # chunk reshape
    xs = xs.reshape(b, c, q, h, p_)
    Bm = Bm.reshape(b, c, q, n)
    Cm = Cm.reshape(b, c, q, n)
    dt = dt.reshape(b, c, q, h)
    dA = dA.reshape(b, c, q, h)
    dA_cs = jnp.cumsum(dA, axis=2)                               # (B,C,Q,H)

    # ---- within-chunk (attention-like dual form) ---------------------------
    # L[l, s'] = exp(dA_cs[l] - dA_cs[s']) for s' <= l
    seg = dA_cs[:, :, :, None, :] - dA_cs[:, :, None, :, :]      # (B,C,Q,Q,H)
    li = jnp.tril(jnp.ones((q, q), bool))
    L = jnp.where(li[None, None, :, :, None], jnp.exp(seg), 0.0).astype(dt_)
    xdt = (xs * dt[..., None].astype(dt_))                       # (B,C,Q,H,P)
    cb = jnp.einsum("bcln,bcsn->bcls", Cm, Bm)                   # (B,C,Q,Q)
    y_diag = jnp.einsum("bcls,bclsh,bcshp->bclhp", cb, L, xdt)

    # ---- per-chunk states + inter-chunk recurrence --------------------------
    decay_out = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)             # (B,C,Q,H)
    states = jnp.einsum("bcsn,bcsh,bcshp->bchpn", Bm, decay_out.astype(dt_), xdt)
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])                    # (B,C,H)

    def scan_fn(carry, inp):
        st, dec = inp                                            # (B,H,P,N), (B,H)
        new = carry * dec[:, :, None, None].astype(carry.dtype) + st
        return new, carry                                        # emit PREVIOUS state

    init = jnp.zeros((b, h, p_, n), dt_)
    final_state, prev_states = jax.lax.scan(
        scan_fn, init, (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1))
    )
    prev_states = prev_states.swapaxes(0, 1)                     # (B,C,H,P,N)

    decay_in = jnp.exp(dA_cs).astype(dt_)                        # (B,C,Q,H)
    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp", Cm, prev_states, decay_in)

    y = (y_diag + y_off + xs * p["D"].astype(dt_)[None, None, None, :, None])
    y = y.reshape(b, s, di)
    y = _gated_norm(y, z, p["norm_scale"])
    out = y @ p["out_proj"].astype(dt_)
    if return_cache:
        cache = {
            "state": final_state.astype(jnp.float32),
            "conv": xbc_raw[:, s - (cfg.ssm_conv - 1):, :],
        }
        return out, cache
    return out


# ---------------------------------------------------------------------------
# Recurrent decode
# ---------------------------------------------------------------------------
def init_ssd_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Dict[str, jax.Array]:
    d, di, p_, h, n = _dims(cfg)
    conv_dim = di + 2 * n
    return {
        "state": jnp.zeros((batch, h, p_, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
    }


def ssd_decode(
    cfg: ModelConfig, p: Params, x: jax.Array, cache: Dict[str, jax.Array]
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One token.  x: (B, 1, D)."""
    d, di, p_, h, n = _dims(cfg)
    b = x.shape[0]
    dt_ = x.dtype

    proj = x[:, 0, :] @ p["in_proj"].astype(dt_)                 # (B, ...)
    z, xbc, dt = _split_proj(cfg, proj)

    # conv ring: window = [cache, new]
    win = jnp.concatenate([cache["conv"], xbc[:, None, :]], axis=1)  # (B,k,Cd)
    conv_out = jnp.einsum("bkc,kc->bc", win, p["conv_w"].astype(dt_)) + p["conv_b"].astype(dt_)
    xbc = jax.nn.silu(conv_out)
    new_conv = win[:, 1:, :]

    xs = xbc[:, :di].reshape(b, h, p_)
    Bm = xbc[:, di : di + n]
    Cm = xbc[:, di + n :]
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    dec = jnp.exp(dtv * A[None, :])                               # (B,H)

    st = cache["state"]
    new_st = st * dec[:, :, None, None] + jnp.einsum(
        "bhp,bn,bh->bhpn", xs.astype(jnp.float32), Bm.astype(jnp.float32), dtv
    )
    y = jnp.einsum("bhpn,bn->bhp", new_st, Cm.astype(jnp.float32)).astype(dt_)
    y = y + xs * p["D"].astype(dt_)[None, :, None]
    y = y.reshape(b, di)
    y = _gated_norm(y, z, p["norm_scale"])
    out = (y @ p["out_proj"].astype(dt_))[:, None, :]
    return out, {"state": new_st, "conv": new_conv}
