"""Activation-sharding hints (logical names -> mesh axes).

Model code annotates activations with LOGICAL axis names; the launcher
installs a policy mapping them to mesh axes before tracing.  Without a
policy every hint is a no-op, so smoke tests and single-device runs are
untouched.

Why this exists: GSPMD propagates parameter shardings to activations, but
boundary ops (embedding gather, logits matmul, MoE dispatch scatter) give it
freedom it sometimes spends badly — the dry-run showed XLA choosing
"involuntary full rematerialization" (replicate-then-reshard) for exactly
those ops, inflating per-device temp memory ~50x.  Pinning three activations
per model removes that freedom.  Policies are also the §Perf hillclimbing
lever: the launcher swaps policies per cell without touching model code.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

__all__ = ["set_policy", "policy", "hint", "use_policy"]

_STATE = threading.local()

Axes = Union[None, str, Tuple[str, ...]]


def set_policy(mapping: Optional[Dict[str, Axes]]) -> None:
    """mapping: logical name ('dp', 'tp', ...) -> mesh axis/axes."""
    _STATE.policy = mapping


def policy() -> Optional[Dict[str, Axes]]:
    return getattr(_STATE, "policy", None)


@contextlib.contextmanager
def use_policy(mapping: Optional[Dict[str, Axes]]):
    prev = policy()
    set_policy(mapping)
    try:
        yield
    finally:
        set_policy(prev)


def _current_mesh():
    """The ambient mesh: ``get_abstract_mesh`` on current jax, the legacy
    with-Mesh thread resource on older releases (same axis_names/shape
    surface for the uses below)."""
    try:
        from jax.sharding import get_abstract_mesh

        return get_abstract_mesh()
    except ImportError:  # pragma: no cover - version-dependent
        from jax.interpreters.pxla import thread_resources

        return thread_resources.env.physical_mesh


def hint(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Constrain ``x``'s sharding; dims named None stay unconstrained."""
    pol = policy()
    if pol is None:
        return x
    mesh = _current_mesh()
    if not mesh.axis_names:          # policy set but no mesh (local runs)
        return x
    if len(logical) != x.ndim:
        raise ValueError(f"hint: {len(logical)} names for rank-{x.ndim} array")
    import numpy as np
    resolved = []
    for dim, name in enumerate(logical):
        ax = pol.get(name) if name else None
        if ax is not None:
            n = ax if isinstance(ax, tuple) else (ax,)
            # divisibility guard mirrors launch.sharding._guard
            size = int(np.prod([mesh.shape[a] for a in n]))
            if size <= 1 or x.shape[dim] % size != 0:
                ax = None
        resolved.append(ax)
    return jax.lax.with_sharding_constraint(x, P(*resolved))
