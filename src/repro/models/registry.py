"""Uniform model interface over the two assembly modules (lm / whisper)."""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple

from repro.configs.base import ModelConfig
from repro.models import lm, whisper

__all__ = ["Model", "get_model"]


class Model(NamedTuple):
    init_params: Callable
    forward: Callable       # training logits
    prefill: Callable
    decode_step: Callable
    init_cache: Callable


_LM = Model(lm.init_params, lm.forward, lm.prefill, lm.decode_step, lm.init_cache)
_ENCDEC = Model(whisper.init_params, whisper.forward, whisper.prefill,
                whisper.decode_step, whisper.init_cache)


def get_model(cfg: ModelConfig) -> Model:
    return _ENCDEC if cfg.is_encdec else _LM
