"""Decoder-only LM assembly: embeddings + scanned blocks + head.

Covers 8 of the 10 assigned architectures (dense llama3/olmo, gemma3's
5-local:1-global interleave, qwen3/dbrx MoE, mamba2 pure-SSD, jamba hybrid,
chameleon early-fusion VLM backbone).  Whisper (enc-dec) lives in
:mod:`repro.models.whisper` and reuses every sublayer from here.

Layer stacking: the repeating BLOCK of LayerSpecs is lax.scan'ned with
params stacked on a leading n_blocks axis (keeps HLO size O(block), compile
time flat in depth — 126-layer llama3 compiles as 1 block x 126).  TAIL
layers (depth not divisible by block length) are unrolled.

Three entry points per model:
  forward(cfg, params, tokens)            -> logits            (training)
  prefill(cfg, params, tokens, cache)     -> logits, cache     (serving)
  decode_step(cfg, params, token, pos, cache) -> logits, cache (serving)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import common as C
from repro.models import moe as M
from repro.models import pshard as PS
from repro.models import ssd as S

__all__ = [
    "init_params",
    "forward",
    "prefill",
    "decode_step",
    "init_cache",
]

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _init_layer(cfg: ModelConfig, spec: LayerSpec, key: jax.Array) -> Params:
    k1, k2 = jax.random.split(key)
    p: Params = {"pre_norm": C.init_norm(cfg, cfg.d_model)}
    if spec.mixer in ("attn", "attn_local", "attn_bidir"):
        p["attn"] = C.init_attn(cfg, k1)
    elif spec.mixer == "ssd":
        p["ssd"] = S.init_ssd(cfg, k1)
    else:
        raise ValueError(spec.mixer)
    if spec.ffn != "none":
        p["post_norm"] = C.init_norm(cfg, cfg.d_model)
        if spec.ffn == "mlp":
            p["mlp"] = C.init_mlp(cfg, k2)
        elif spec.ffn == "moe":
            p["moe"] = M.init_moe(cfg, k2)
        else:
            raise ValueError(spec.ffn)
    return p


def _init_block(cfg: ModelConfig, key: jax.Array) -> Params:
    ks = jax.random.split(key, len(cfg.block))
    return {f"l{i}": _init_layer(cfg, spec, ks[i]) for i, spec in enumerate(cfg.block)}


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    ks = jax.random.split(key, 4 + len(cfg.tail))
    p: Params = {
        "embed": jax.random.normal(ks[0], (cfg.padded_vocab, cfg.d_model),
                                   jnp.float32) / math.sqrt(cfg.d_model),
        "final_norm": C.init_norm(cfg, cfg.d_model),
    }
    # stacked block params: vmap init over the n_blocks axis
    block_keys = jax.random.split(ks[1], cfg.n_blocks)
    p["blocks"] = jax.vmap(lambda k: _init_block(cfg, k))(block_keys)
    for i, spec in enumerate(cfg.tail):
        p[f"tail{i}"] = _init_layer(cfg, spec, ks[4 + i])
    if not cfg.tie_embeddings:
        p["lm_head"] = jax.random.normal(
            ks[2], (cfg.d_model, cfg.padded_vocab), jnp.float32) / math.sqrt(cfg.d_model)
    return p


# ---------------------------------------------------------------------------
# Layer application (training / full-sequence)
# ---------------------------------------------------------------------------
_KIND = {"attn": "causal", "attn_local": "local", "attn_bidir": "bidir"}


def _apply_layer(cfg: ModelConfig, spec: LayerSpec, p: Params, x: jax.Array,
                 q_chunk: int) -> jax.Array:
    # Sequence-parallel residual stream (OPT-IN via policy 'sp' -> model
    # axis): the residual boundary each scan step saves for backward is
    # sharded S/|tp| per device instead of replicated — the 405B-on-16GB
    # lever.  Measured trade (EXPERIMENTS.md §Perf iter C3): temp -23 GB,
    # HBM traffic -36%, but +2100 s of reshard collectives under our cost
    # model — so it stays opt-in, not default.
    sp = bool((PS.policy() or {}).get("sp"))
    if sp:
        x = PS.hint(x, "dp", "sp", None)
    h = C.norm_apply(cfg, x, C._norm_scale(p["pre_norm"]))
    if spec.mixer == "ssd":
        x = x + S.ssd_forward(cfg, p["ssd"], h)
    else:
        x = x + C.attn_forward(cfg, p["attn"], h, kind=_KIND[spec.mixer], q_chunk=q_chunk)
    if spec.ffn != "none":
        if sp:
            x = PS.hint(x, "dp", "sp", None)
        h = C.norm_apply(cfg, x, C._norm_scale(p["post_norm"]))
        if spec.ffn == "mlp":
            x = x + C.mlp_forward(p["mlp"], h)
        else:
            x = x + M.moe_forward(cfg, p["moe"], h)
    return x


def _apply_block(cfg: ModelConfig, bp: Params, x: jax.Array, q_chunk: int) -> jax.Array:
    for i, spec in enumerate(cfg.block):
        x = _apply_layer(cfg, spec, bp[f"l{i}"], x, q_chunk)
    return x


def _embed(cfg: ModelConfig, params: Params, tokens: jax.Array,
           dtype=jnp.bfloat16) -> jax.Array:
    x = params["embed"].astype(dtype)[tokens]
    return PS.hint(x, "dp", None, None)


def _head(cfg: ModelConfig, params: Params, x: jax.Array) -> jax.Array:
    x = C.norm_apply(cfg, x, C._norm_scale(params["final_norm"]))
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ w.astype(x.dtype)).astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab:   # mask the padding rows to -inf
        vid = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        logits = jnp.where(vid < cfg.vocab, logits, -1e30)
    return PS.hint(logits, "dp", None, "tp")


def forward(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,              # (B, S) int32
    q_chunk: int = 0,
    dtype=jnp.bfloat16,
) -> jax.Array:
    """Training forward: causal logits (B, S, V) float32."""
    x = _embed(cfg, params, tokens, dtype)

    body = lambda bp, h: _apply_block(cfg, bp, h, q_chunk)
    if cfg.remat:
        pol = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
               if cfg.remat_policy == "dots"
               else jax.checkpoint_policies.nothing_saveable)
        body = jax.checkpoint(body, policy=pol)

    if cfg.scan_layers and cfg.n_blocks > 1:
        def scan_fn(h, bp):
            return body(bp, h), None
        x, _ = jax.lax.scan(scan_fn, x, params["blocks"])
    else:
        for j in range(cfg.n_blocks):
            bp = jax.tree.map(lambda a: a[j], params["blocks"])
            x = body(bp, x)
    for i, spec in enumerate(cfg.tail):
        x = _apply_layer(cfg, spec, params[f"tail{i}"], x, q_chunk)
    return _head(cfg, params, x)


# ---------------------------------------------------------------------------
# Serving: cache init / prefill / decode
# ---------------------------------------------------------------------------
def _layer_cache(cfg: ModelConfig, spec: LayerSpec, batch: int, s_max: int,
                 dtype) -> Params:
    if spec.mixer == "ssd":
        return S.init_ssd_cache(cfg, batch, dtype)
    length = min(cfg.window, s_max) if (spec.mixer == "attn_local" and cfg.window) else s_max
    kv, hd = cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((batch, length, kv, hd), dtype),
        "v": jnp.zeros((batch, length, kv, hd), dtype),
    }


def init_cache(cfg: ModelConfig, batch: int, s_max: int, dtype=jnp.bfloat16) -> Params:
    cache: Params = {}
    block_caches = [
        {f"l{i}": _layer_cache(cfg, spec, batch, s_max, dtype)
         for i, spec in enumerate(cfg.block)}
        for _ in range(cfg.n_blocks)
    ]
    cache["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *block_caches) \
        if cfg.n_blocks > 1 else jax.tree.map(lambda x: x[None], block_caches[0])
    for i, spec in enumerate(cfg.tail):
        cache[f"tail{i}"] = _layer_cache(cfg, spec, batch, s_max, dtype)
    return cache


def _decode_layer(cfg: ModelConfig, spec: LayerSpec, p: Params, x: jax.Array,
                  lc: Params, pos: jax.Array) -> Tuple[jax.Array, Params]:
    h = C.norm_apply(cfg, x, C._norm_scale(p["pre_norm"]))
    if spec.mixer == "ssd":
        out, lc = S.ssd_decode(cfg, p["ssd"], h, lc)
        x = x + out
    else:
        out, lc = C.attn_decode(cfg, p["attn"], h, lc, pos, kind=_KIND[spec.mixer])
        x = x + out
    if spec.ffn != "none":
        h = C.norm_apply(cfg, x, C._norm_scale(p["post_norm"]))
        x = x + (C.mlp_forward(p["mlp"], h) if spec.ffn == "mlp"
                 else M.moe_forward(cfg, p["moe"], h))
    return x, lc


def decode_step(
    cfg: ModelConfig,
    params: Params,
    token: jax.Array,               # (B,) int32 — the newest token
    pos: jax.Array,                 # scalar int32 — its position
    cache: Params,
    dtype=jnp.bfloat16,
) -> Tuple[jax.Array, Params]:
    """One decode step: (B, V) float32 logits for the NEXT token + new cache."""
    x = _embed(cfg, params, token[:, None], dtype)

    def block_body(h, xs):
        bp, bc = xs
        new_bc = {}
        for i, spec in enumerate(cfg.block):
            h, new_bc[f"l{i}"] = _decode_layer(cfg, spec, bp[f"l{i}"], h, bc[f"l{i}"], pos)
        return h, new_bc

    if cfg.scan_layers and cfg.n_blocks > 1:
        x, new_blocks = jax.lax.scan(block_body, x, (params["blocks"], cache["blocks"]))
    else:
        new_list = []
        for j in range(cfg.n_blocks):
            bp = jax.tree.map(lambda a: a[j], params["blocks"])
            bc = jax.tree.map(lambda a: a[j], cache["blocks"])
            x, nb = block_body(x, (bp, bc))
            new_list.append(nb)
        new_blocks = jax.tree.map(lambda *xs: jnp.stack(xs), *new_list)
    new_cache: Params = {"blocks": new_blocks}
    for i, spec in enumerate(cfg.tail):
        x, new_cache[f"tail{i}"] = _decode_layer(
            cfg, spec, params[f"tail{i}"], x, cache[f"tail{i}"], pos
        )
    logits = _head(cfg, params, x)[:, 0, :]
    return logits, new_cache


def prefill(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,              # (B, S)
    q_chunk: int = 0,
    dtype=jnp.bfloat16,
) -> Tuple[jax.Array, Params]:
    """Process the whole prompt, return last-position logits + filled cache.

    Lowered for the prefill_32k cells.  KV caches are emitted at prompt
    length; the serving engine right-pads them into the decode-time ring.
    """
    b, s = tokens.shape
    x = _embed(cfg, params, tokens, dtype)
    caches: Params = {}

    def block_fn(h, bp):
        new_bc = {}
        for i, spec in enumerate(cfg.block):
            p = bp[f"l{i}"]
            hn = C.norm_apply(cfg, h, C._norm_scale(p["pre_norm"]))
            if spec.mixer == "ssd":
                out, sc = S.ssd_forward(cfg, p["ssd"], hn, return_cache=True)
                new_bc[f"l{i}"] = sc
                h = h + out
            else:
                out, kvc = C.attn_prefill(cfg, p["attn"], hn, _KIND[spec.mixer], q_chunk)
                new_bc[f"l{i}"] = kvc
                h = h + out
            if spec.ffn != "none":
                hn = C.norm_apply(cfg, h, C._norm_scale(p["post_norm"]))
                h = h + (C.mlp_forward(p["mlp"], hn) if spec.ffn == "mlp"
                         else M.moe_forward(cfg, p["moe"], hn))
        return h, new_bc

    if cfg.scan_layers and cfg.n_blocks > 1:
        x, caches["blocks"] = jax.lax.scan(lambda h, bp: block_fn(h, bp), x, params["blocks"])
    else:
        outs = []
        for j in range(cfg.n_blocks):
            bp = jax.tree.map(lambda a: a[j], params["blocks"])
            x, bc = block_fn(x, bp)
            outs.append(bc)
        caches["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    for i, spec in enumerate(cfg.tail):
        hn = C.norm_apply(cfg, x, C._norm_scale(params[f"tail{i}"]["pre_norm"]))
        if spec.mixer == "ssd":
            out, caches[f"tail{i}"] = S.ssd_forward(
                cfg, params[f"tail{i}"]["ssd"], hn, return_cache=True)
            x = x + out
        else:
            out, kvc = C.attn_prefill(cfg, params[f"tail{i}"]["attn"], hn,
                                      _KIND[spec.mixer], q_chunk)
            caches[f"tail{i}"] = kvc
            x = x + out
        if spec.ffn != "none":
            p = params[f"tail{i}"]
            hn = C.norm_apply(cfg, x, C._norm_scale(p["post_norm"]))
            x = x + (C.mlp_forward(p["mlp"], hn) if spec.ffn == "mlp"
                     else M.moe_forward(cfg, p["moe"], hn))
    logits = _head(cfg, params, x[:, -1:, :])[:, 0, :]
    return logits, caches
