"""Whisper-style encoder-decoder backbone (audio family).

Per the assignment the conv/mel FRONTEND IS A STUB: ``input_specs()`` hands
the encoder precomputed frame embeddings (B, enc_seq=1500, d_model).  The
backbone is faithful: bidirectional encoder, causal decoder with per-layer
cross-attention to the encoder output.  Positional signal is sinusoidal
(parameter-free) rather than Whisper's learned table — recorded as a
deviation in DESIGN.md (the learned table adds nothing to the systems
questions studied here).

Decode-time cache = per-layer self-attn KV ring + per-layer cross-attn KV
(computed ONCE from the encoder output at prefill, reused every step).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common as C

__all__ = ["init_params", "forward", "prefill", "decode_step", "init_cache"]

Params = Dict[str, Any]


def _logits(cfg: ModelConfig, params: Params, x: jax.Array, dtype) -> jax.Array:
    logits = (x @ params["embed"].T.astype(dtype)).astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab:
        vid = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        logits = jnp.where(vid < cfg.vocab, logits, -1e30)
    return logits


def _sinusoid(s: int, d: int, dtype) -> jax.Array:
    pos = jnp.arange(s, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10_000.0, 2.0 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def _init_enc_layer(cfg: ModelConfig, key: jax.Array) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "pre_norm": C.init_norm(cfg, cfg.d_model),
        "attn": C.init_attn(cfg, k1),
        "post_norm": C.init_norm(cfg, cfg.d_model),
        "mlp": C.init_mlp(cfg, k2),
    }


def _init_dec_layer(cfg: ModelConfig, key: jax.Array) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "pre_norm": C.init_norm(cfg, cfg.d_model),
        "attn": C.init_attn(cfg, k1),
        "cross_norm": C.init_norm(cfg, cfg.d_model),
        "cross": C.init_attn(cfg, k2, cross=True),
        "post_norm": C.init_norm(cfg, cfg.d_model),
        "mlp": C.init_mlp(cfg, k3),
    }


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    ks = jax.random.split(key, 4)
    enc_keys = jax.random.split(ks[0], cfg.enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "embed": jax.random.normal(ks[2], (cfg.padded_vocab, cfg.d_model),
                                   jnp.float32) / math.sqrt(cfg.d_model),
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(cfg, k))(enc_keys),
        "enc_norm": C.init_norm(cfg, cfg.d_model),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(cfg, k))(dec_keys),
        "final_norm": C.init_norm(cfg, cfg.d_model),
    }


# ---------------------------------------------------------------------------
# Encoder (frames already embedded by the stub frontend)
# ---------------------------------------------------------------------------
def encode(cfg: ModelConfig, params: Params, frames: jax.Array,
           q_chunk: int = 0) -> jax.Array:
    b, s, d = frames.shape
    x = frames + _sinusoid(s, d, frames.dtype)[None]

    def body(h, lp):
        hn = C.norm_apply(cfg, h, C._norm_scale(lp["pre_norm"]))
        h = h + C.attn_forward(cfg, lp["attn"], hn, kind="bidir", q_chunk=q_chunk)
        hn = C.norm_apply(cfg, h, C._norm_scale(lp["post_norm"]))
        return h + C.mlp_forward(lp["mlp"], hn), None

    fn = body
    if cfg.remat:
        fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(fn, x, params["enc_layers"])
    return C.norm_apply(cfg, x, C._norm_scale(params["enc_norm"]))


# ---------------------------------------------------------------------------
# Decoder
# ---------------------------------------------------------------------------
def _dec_body(cfg: ModelConfig, h: jax.Array, lp: Params, enc: jax.Array,
              q_chunk: int) -> jax.Array:
    hn = C.norm_apply(cfg, h, C._norm_scale(lp["pre_norm"]))
    h = h + C.attn_forward(cfg, lp["attn"], hn, kind="causal", q_chunk=q_chunk)
    hn = C.norm_apply(cfg, h, C._norm_scale(lp["cross_norm"]))
    h = h + C.cross_attn_forward(cfg, lp["cross"], hn, enc)
    hn = C.norm_apply(cfg, h, C._norm_scale(lp["post_norm"]))
    return h + C.mlp_forward(lp["mlp"], hn)


def forward(
    cfg: ModelConfig,
    params: Params,
    frames: jax.Array,              # (B, enc_seq, D) — stub frontend output
    tokens: jax.Array,              # (B, S) int32
    q_chunk: int = 0,
    dtype=jnp.bfloat16,
) -> jax.Array:
    enc = encode(cfg, params, frames.astype(dtype), q_chunk)
    b, s = tokens.shape
    x = params["embed"].astype(dtype)[tokens] + _sinusoid(s, cfg.d_model, dtype)[None]

    def body(h, lp):
        return _dec_body(cfg, h, lp, enc, q_chunk), None

    fn = body
    if cfg.remat:
        fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(fn, x, params["dec_layers"])
    x = C.norm_apply(cfg, x, C._norm_scale(params["final_norm"]))
    return _logits(cfg, params, x, dtype)


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, s_max: int, dtype=jnp.bfloat16) -> Params:
    kv, hd = cfg.n_kv_heads, cfg.hd
    L = cfg.n_layers
    return {
        "k": jnp.zeros((L, batch, s_max, kv, hd), dtype),
        "v": jnp.zeros((L, batch, s_max, kv, hd), dtype),
        "ck": jnp.zeros((L, batch, cfg.enc_seq, kv, hd), dtype),
        "cv": jnp.zeros((L, batch, cfg.enc_seq, kv, hd), dtype),
    }


def encode_into_cache(cfg: ModelConfig, params: Params, frames: jax.Array,
                      cache: Params, q_chunk: int = 0) -> Params:
    """Run the encoder and fill the per-layer cross-KV entries of ``cache``
    (decode-only path: serve audio without prefilling any decoder tokens)."""
    enc = encode(cfg, params, frames, q_chunk)

    def per_layer(lp):
        kvs = C.cross_kv(cfg, lp["cross"], enc)
        return kvs["ck"], kvs["cv"]

    ck, cv = jax.vmap(per_layer)(params["dec_layers"])
    return {**cache, "ck": ck.astype(cache["ck"].dtype), "cv": cv.astype(cache["cv"].dtype)}


def prefill(
    cfg: ModelConfig,
    params: Params,
    frames: jax.Array,
    tokens: jax.Array,
    q_chunk: int = 0,
    dtype=jnp.bfloat16,
) -> Tuple[jax.Array, Params]:
    enc = encode(cfg, params, frames.astype(dtype), q_chunk)
    b, s = tokens.shape
    x = params["embed"].astype(dtype)[tokens] + _sinusoid(s, cfg.d_model, dtype)[None]

    def body(h, lp):
        hn = C.norm_apply(cfg, h, C._norm_scale(lp["pre_norm"]))
        out, kvc = C.attn_prefill(cfg, lp["attn"], hn, "causal", q_chunk)
        h = h + out
        hn = C.norm_apply(cfg, h, C._norm_scale(lp["cross_norm"]))
        ckv = C.cross_kv(cfg, lp["cross"], enc)
        h = h + C.cross_attn_decode(cfg, lp["cross"], hn, ckv)
        hn = C.norm_apply(cfg, h, C._norm_scale(lp["post_norm"]))
        h = h + C.mlp_forward(lp["mlp"], hn)
        return h, {"k": kvc["k"], "v": kvc["v"], "ck": ckv["ck"], "cv": ckv["cv"]}

    x, cc = jax.lax.scan(body, x, params["dec_layers"])
    x = C.norm_apply(cfg, x[:, -1:, :], C._norm_scale(params["final_norm"]))
    logits = _logits(cfg, params, x, dtype)[:, 0, :]
    return logits, cc


def decode_step(
    cfg: ModelConfig,
    params: Params,
    token: jax.Array,               # (B,)
    pos: jax.Array,                 # scalar
    cache: Params,
    dtype=jnp.bfloat16,
) -> Tuple[jax.Array, Params]:
    b = token.shape[0]
    x = params["embed"].astype(dtype)[token[:, None]]
    x = x + jax.lax.dynamic_slice_in_dim(
        _sinusoid(cache["k"].shape[2], cfg.d_model, dtype), pos, 1, axis=0
    )[None]

    def body(h, xs):
        lp, kc, vc, ck, cv = xs
        hn = C.norm_apply(cfg, h, C._norm_scale(lp["pre_norm"]))
        out, kvc = C.attn_decode(cfg, lp["attn"], hn, {"k": kc, "v": vc}, pos, "causal")
        h = h + out
        hn = C.norm_apply(cfg, h, C._norm_scale(lp["cross_norm"]))
        h = h + C.cross_attn_decode(cfg, lp["cross"], hn, {"ck": ck, "cv": cv})
        hn = C.norm_apply(cfg, h, C._norm_scale(lp["post_norm"]))
        h = h + C.mlp_forward(lp["mlp"], hn)
        return h, (kvc["k"], kvc["v"])

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"], cache["ck"], cache["cv"])
    )
    x = C.norm_apply(cfg, x, C._norm_scale(params["final_norm"]))
    logits = _logits(cfg, params, x, dtype)[:, 0, :]
    return logits, {"k": nk, "v": nv, "ck": cache["ck"], "cv": cache["cv"]}
