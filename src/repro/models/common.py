"""Shared model components: norms, RoPE, GQA attention, gated MLP.

Functional style throughout: params are plain dicts of jnp arrays, every
entry point takes (cfg, params, ...).  Sharding is by annotation only —
:mod:`repro.launch.sharding` maps the same dict structure to PartitionSpecs;
nothing here touches the mesh.

Attention serves four duties from one implementation:
  * training   — full-sequence causal (optionally sliding-window) with
                 query-chunking (lax.scan over q blocks) so the score matrix
                 never exceeds (q_chunk x S) per head: required for 32k+
                 prefill on 16 GB HBM;
  * prefill    — same as training path, returns the populated KV cache;
  * decode     — single-query step against a cache (one new token);
  * encoder    — bidirectional (no mask), whisper's stub-frontend encoder.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

__all__ = [
    "rmsnorm",
    "nonparam_ln",
    "norm_apply",
    "rope",
    "init_attn",
    "attn_forward",
    "attn_decode",
    "init_mlp",
    "mlp_forward",
    "init_dense",
    "cross_attn_forward",
    "cross_attn_decode",
]

Params = Dict[str, jax.Array]


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def nonparam_ln(x: jax.Array, _scale=None, eps: float = 1e-5) -> jax.Array:
    """OLMo's non-parametric LayerNorm: no scale, no bias."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def norm_apply(cfg: ModelConfig, x: jax.Array, scale: Optional[jax.Array]) -> jax.Array:
    if cfg.norm == "nonparam_ln":
        return nonparam_ln(x)
    return rmsnorm(x, scale)


def init_norm(cfg: ModelConfig, d: int) -> Params:
    if cfg.norm == "nonparam_ln":
        return {}  # no parameters at all
    return {"scale": jnp.zeros((d,), jnp.float32)}


def _norm_scale(p: Params) -> Optional[jax.Array]:
    return p.get("scale")


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32) * (math.log(theta) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA; causal / sliding-window / bidirectional; cached decode)
# ---------------------------------------------------------------------------
def init_attn(cfg: ModelConfig, key: jax.Array, cross: bool = False) -> Params:
    d, hd = cfg.d_model, cfg.hd
    h, kv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    p = {
        "wq": jax.random.normal(ks[0], (d, h * hd), jnp.float32) * s,
        "wk": jax.random.normal(ks[1], (d, kv * hd), jnp.float32) * s,
        "wv": jax.random.normal(ks[2], (d, kv * hd), jnp.float32) * s,
        "wo": jax.random.normal(ks[3], (h * hd, d), jnp.float32) * s,
    }
    if cfg.qk_norm and not cross:
        p["q_scale"] = jnp.zeros((hd,), jnp.float32)
        p["k_scale"] = jnp.zeros((hd,), jnp.float32)
    return p


def _project_qkv(cfg: ModelConfig, p: Params, xq: jax.Array, xkv: jax.Array,
                 q_pos, k_pos, use_rope: bool):
    b = xq.shape[0]
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = xq.dtype
    q = (xq @ p["wq"].astype(dt)).reshape(b, -1, h, hd)
    k = (xkv @ p["wk"].astype(dt)).reshape(b, -1, kv, hd)
    v = (xkv @ p["wv"].astype(dt)).reshape(b, -1, kv, hd)
    if cfg.qk_norm and "q_scale" in p:
        q = rmsnorm(q, p["q_scale"])
        k = rmsnorm(k, p["k_scale"])
    if use_rope:
        q = rope(q, q_pos, cfg.rope_theta)
        k = rope(k, k_pos, cfg.rope_theta)
    return q, k, v


def _sdpa(cfg: ModelConfig, q: jax.Array, k: jax.Array, v: jax.Array,
          mask: Optional[jax.Array]) -> jax.Array:
    """q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd); mask: (Sq, Sk) bool or None."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k) / math.sqrt(hd)
    scores = scores.astype(jnp.float32)
    if mask is not None:
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(b, sq, h * hd)


def _make_mask(sq: int, sk: int, q_off, kind: str, window: int) -> Optional[jax.Array]:
    if kind == "bidir":
        return None
    qi = q_off + jnp.arange(sq)[:, None]
    kj = jnp.arange(sk)[None, :]
    mask = kj <= qi
    if kind == "local" and window > 0:
        mask &= kj > qi - window
    return mask


def attn_forward(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,                 # (B, S, D)
    kind: str = "causal",         # causal | local | bidir
    q_chunk: int = 0,             # 0 = no chunking
) -> jax.Array:
    b, s, _ = x.shape
    pos = jnp.arange(s)[None, :]
    use_rope = kind != "bidir"
    q, k, v = _project_qkv(cfg, p, x, x, pos, pos, use_rope)
    if q_chunk and s > q_chunk and s % q_chunk == 0:
        nq = s // q_chunk
        qs = q.reshape(b, nq, q_chunk, cfg.n_heads, cfg.hd).swapaxes(0, 1)

        def body(carry, args):
            i, qc = args
            mask = _make_mask(q_chunk, s, i * q_chunk, kind, cfg.window)
            return carry, _sdpa(cfg, qc, k, v, mask)

        _, outs = jax.lax.scan(body, 0, (jnp.arange(nq), qs))
        out = outs.swapaxes(0, 1).reshape(b, s, cfg.n_heads * cfg.hd)
    else:
        mask = _make_mask(s, s, 0, kind, cfg.window)
        out = _sdpa(cfg, q, k, v, mask)
    return out @ p["wo"].astype(x.dtype)


def attn_prefill(
    cfg: ModelConfig, p: Params, x: jax.Array, kind: str, q_chunk: int = 0
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Forward + return the KV cache (positions [0, S) filled).

    q-chunked exactly like :func:`attn_forward`: the score matrix never
    exceeds (q_chunk x S) per head — required for 32k prefill in 16 GB HBM.
    """
    b, s, _ = x.shape
    pos = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(cfg, p, x, x, pos, pos, kind != "bidir")
    if q_chunk and s > q_chunk and s % q_chunk == 0:
        nq = s // q_chunk
        qs = q.reshape(b, nq, q_chunk, cfg.n_heads, cfg.hd).swapaxes(0, 1)

        def body(carry, args):
            i, qc = args
            mask = _make_mask(q_chunk, s, i * q_chunk, kind, cfg.window)
            return carry, _sdpa(cfg, qc, k, v, mask)

        _, outs = jax.lax.scan(body, 0, (jnp.arange(nq), qs))
        out = outs.swapaxes(0, 1).reshape(b, s, cfg.n_heads * cfg.hd)
    else:
        mask = _make_mask(s, s, 0, kind, cfg.window)
        out = _sdpa(cfg, q, k, v, mask)
    return out @ p["wo"].astype(x.dtype), {"k": k, "v": v}


def attn_decode(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,                 # (B, 1, D)
    cache: Dict[str, jax.Array],  # k/v: (B, S_max, KV, hd)
    pos: jax.Array,               # scalar int32: index of the new token
    kind: str = "causal",
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    b = x.shape[0]
    q, k_new, v_new = _project_qkv(cfg, p, x, x, pos[None, None], pos[None, None], True)
    s_max = cache["k"].shape[1]
    # ring buffer when the cache is exactly window-sized (sliding-window layer)
    ring = kind == "local" and cfg.window > 0 and s_max <= cfg.window
    slot = jnp.mod(pos, s_max) if ring else pos
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype), (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype), (0, slot, 0, 0))
    sk = k.shape[1]
    kj = jnp.arange(sk)[None, :]
    if ring:
        # slots [0, min(pos+1, W)) hold the last `window` tokens
        mask = kj < jnp.minimum(pos + 1, sk)
    elif kind == "local" and cfg.window > 0:
        mask = (kj <= pos) & (kj > pos - cfg.window)
    else:
        mask = kj <= pos
    out = _sdpa(cfg, q, k, v, mask.reshape(1, sk))
    return out @ p["wo"].astype(x.dtype), {"k": k, "v": v}


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder): K/V from the encoder, precomputed once
# ---------------------------------------------------------------------------
def cross_attn_forward(cfg: ModelConfig, p: Params, x: jax.Array, enc: jax.Array) -> jax.Array:
    b, s, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x, enc, None, None, use_rope=False)
    return _sdpa(cfg, q, k, v, None) @ p["wo"].astype(x.dtype)


def cross_kv(cfg: ModelConfig, p: Params, enc: jax.Array) -> Dict[str, jax.Array]:
    b = enc.shape[0]
    kv, hd = cfg.n_kv_heads, cfg.hd
    dt = enc.dtype
    return {
        "ck": (enc @ p["wk"].astype(dt)).reshape(b, -1, kv, hd),
        "cv": (enc @ p["wv"].astype(dt)).reshape(b, -1, kv, hd),
    }


def cross_attn_decode(cfg: ModelConfig, p: Params, x: jax.Array,
                      ckv: Dict[str, jax.Array]) -> jax.Array:
    b = x.shape[0]
    h, hd = cfg.n_heads, cfg.hd
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(b, -1, h, hd)
    out = _sdpa(cfg, q, ckv["ck"].astype(dt), ckv["cv"].astype(dt), None)
    return out @ p["wo"].astype(dt)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU)
# ---------------------------------------------------------------------------
def init_mlp(cfg: ModelConfig, key: jax.Array) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "wi": jax.random.normal(ks[0], (d, f), jnp.float32) / math.sqrt(d),
        "wo": jax.random.normal(ks[2], (f, d), jnp.float32) / math.sqrt(f),
    }
    if cfg.gated_mlp:
        p["wg"] = jax.random.normal(ks[1], (d, f), jnp.float32) / math.sqrt(d)
    return p


def mlp_forward(p: Params, x: jax.Array) -> jax.Array:
    dt = x.dtype
    if "wg" in p:  # SwiGLU
        h = jax.nn.silu(x @ p["wg"].astype(dt)) * (x @ p["wi"].astype(dt))
    else:          # GELU 2-matrix (whisper)
        h = jax.nn.gelu(x @ p["wi"].astype(dt))
    return h @ p["wo"].astype(dt)


def init_dense(key: jax.Array, shape: Tuple[int, ...], scale: float) -> jax.Array:
    return jax.random.normal(key, shape, jnp.float32) * scale
