"""Columnar in-memory Table — the substrate TensProv instruments.

A deliberately Pandas-shaped but array-resident container: one float32 value
matrix + a null mask + a preserved integer index.  Categorical values are
stored as integer codes in float32 (a ``vocab`` per column keeps the labels).
The preserved ``index`` is what the paper's hybrid capture exploits for
index-preserving operations (filter et al., §III-B).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

__all__ = ["Table"]


@dataclasses.dataclass
class Table:
    columns: List[str]
    data: np.ndarray                      # (n_rows, n_cols) float32
    null: np.ndarray                      # (n_rows, n_cols) bool
    index: np.ndarray                     # (n_rows,) int64, dataframe index
    vocab: Dict[str, list] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        self.data = np.asarray(self.data, dtype=np.float32)
        if self.data.ndim != 2:
            raise ValueError("data must be 2-D (rows x cols)")
        n, c = self.data.shape
        if len(self.columns) != c:
            raise ValueError(f"{len(self.columns)} names for {c} columns")
        if self.null is None:
            self.null = np.zeros((n, c), dtype=bool)
        self.null = np.asarray(self.null, dtype=bool)
        if self.index is None:
            self.index = np.arange(n, dtype=np.int64)
        self.index = np.asarray(self.index, dtype=np.int64)

    # -- construction --------------------------------------------------------
    @staticmethod
    def from_columns(cols: Dict[str, np.ndarray], null: Optional[Dict[str, np.ndarray]] = None) -> "Table":
        names = list(cols)
        data = np.stack([np.asarray(cols[c], dtype=np.float32) for c in names], axis=1)
        n = data.shape[0]
        nullm = np.zeros_like(data, dtype=bool)
        if null:
            for j, c in enumerate(names):
                if c in null:
                    nullm[:, j] = null[c]
        nullm |= np.isnan(data)
        return Table(columns=names, data=data, null=nullm, index=np.arange(n, dtype=np.int64))

    # -- shape ----------------------------------------------------------------
    @property
    def n_rows(self) -> int:
        return int(self.data.shape[0])

    @property
    def n_cols(self) -> int:
        return int(self.data.shape[1])

    def col(self, name: str) -> np.ndarray:
        return self.data[:, self.columns.index(name)]

    def col_null(self, name: str) -> np.ndarray:
        return self.null[:, self.columns.index(name)]

    def cid(self, name: str) -> int:
        return self.columns.index(name)

    # -- row/col selection (no provenance — used internally) ------------------
    def take_rows(self, rows: np.ndarray, keep_index: bool = True) -> "Table":
        rows = np.asarray(rows)
        return Table(
            columns=list(self.columns),
            data=self.data[rows],
            null=self.null[rows],
            index=self.index[rows] if keep_index else np.arange(len(rows), dtype=np.int64),
            vocab=dict(self.vocab),
        )

    def take_cols(self, names: Sequence[str]) -> "Table":
        ids = [self.columns.index(c) for c in names]
        return Table(
            columns=list(names),
            data=self.data[:, ids],
            null=self.null[:, ids],
            index=self.index.copy(),
            vocab={c: v for c, v in self.vocab.items() if c in names},
        )

    def copy(self) -> "Table":
        return Table(
            columns=list(self.columns),
            data=self.data.copy(),
            null=self.null.copy(),
            index=self.index.copy(),
            vocab=dict(self.vocab),
        )

    def nbytes(self) -> int:
        return int(self.data.nbytes + self.null.nbytes + self.index.nbytes)

    def row_tuple(self, i: int) -> tuple:
        """Value identity of a row (nulls normalized) — used by set-semantics
        canonicalization and by the Chapman baseline's frame diffing."""
        vals = self.data[i].copy()
        vals[self.null[i]] = np.nan
        return tuple(-0.0 if v == 0 else v for v in vals.tolist())

    def duplicate_groups(self) -> np.ndarray:
        """Set-semantics support (paper §III-C.a): ``groups[i]`` = smallest
        row index whose VALUES equal row i's (nulls compare equal)."""
        clean = np.where(self.null, np.float32(np.nan), self.data)
        view = np.ascontiguousarray(clean).view(np.uint32).reshape(self.n_rows, -1)
        first: dict = {}
        groups = np.empty(self.n_rows, dtype=np.int32)
        for i in range(self.n_rows):
            key = view[i].tobytes()
            groups[i] = first.setdefault(key, i)
        return groups
