"""The data-preparation operations of paper Table I, with capture payloads.

Every public op returns ``(out_table, CaptureInfo)``.  The CaptureInfo carries
exactly the payload the paper's hybrid capture needs:

* index-preserving ops (filter/transform/vertical ops) — observation-based:
  the kept-row list comes from comparing preserved dataframe indices, no
  content diffing (paper §III-B);
* the join — active capture: the implementation threads row-ids through the
  match (the instrumented-ID-column strategy of §V), so provenance falls out
  of the matching itself.

Value math is vectorized numpy/jnp; ops are deterministic given their params
so non-materialized intermediates can be recomputed per-record (§III-E).
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.opcat import AttrMap, CaptureInfo, OpCategory
from repro.core.schema import Bitset
from repro.dataprep.table import Table

__all__ = [
    "value_transform",
    "binarize",
    "normalize",
    "impute",
    "discretize",
    "select_columns",
    "drop_columns",
    "filter_rows",
    "undersample",
    "onehot",
    "string_indexer",
    "space_transform",
    "oversample",
    "join",
    "append",
    "TRANSFORM_FNS",
]

OpResult = Tuple[Table, CaptureInfo]


# ---------------------------------------------------------------------------
# Data transformation (identity tensor; identity attr map)
# ---------------------------------------------------------------------------
TRANSFORM_FNS = {
    "log1p": lambda x, p: np.log1p(np.maximum(x, 0.0)),
    "scale": lambda x, p: x * p.get("factor", 1.0) + p.get("offset", 0.0),
    "clip": lambda x, p: np.clip(x, p.get("lo", -np.inf), p.get("hi", np.inf)),
    "binarize": lambda x, p: (x > p["threshold"]).astype(np.float32),
}


def value_transform(t: Table, col: str, fn: str, **fn_params) -> OpResult:
    """Localized TRANSFORM: y = f(x) per cell."""
    out = t.copy()
    j = t.cid(col)
    out.data[:, j] = TRANSFORM_FNS[fn](t.data[:, j], fn_params).astype(np.float32)
    info = CaptureInfo(
        op_name=f"transform:{fn}",
        category=OpCategory.TRANSFORM,
        contextual=False,
        n_out=t.n_rows,
        n_in=[t.n_rows],
        attr_maps=[AttrMap(kind="identity")],
        params={"col": col, "fn": fn, "fn_params": fn_params},
    )
    return out, info


def binarize(t: Table, col: str, threshold: float) -> OpResult:
    return value_transform(t, col, "binarize", threshold=threshold)


def normalize(t: Table, cols: Sequence[str], kind: str = "zscore") -> OpResult:
    """Contextual TRANSFORM: needs whole-column statistics (paper §III-E)."""
    out = t.copy()
    stats = {}
    for c in cols:
        j = t.cid(c)
        x = t.data[:, j]
        valid = ~t.null[:, j]
        if kind == "zscore":
            mu = float(x[valid].mean()) if valid.any() else 0.0
            sd = float(x[valid].std()) or 1.0
            out.data[:, j] = (x - mu) / sd
            stats[c] = (mu, sd)
        elif kind == "minmax":
            lo = float(x[valid].min()) if valid.any() else 0.0
            hi = float(x[valid].max()) if valid.any() else 1.0
            out.data[:, j] = (x - lo) / ((hi - lo) or 1.0)
            stats[c] = (lo, hi)
        else:
            raise ValueError(kind)
    info = CaptureInfo(
        op_name=f"normalize:{kind}",
        category=OpCategory.TRANSFORM,
        contextual=True,
        n_out=t.n_rows,
        n_in=[t.n_rows],
        attr_maps=[AttrMap(kind="identity")],
        params={"cols": list(cols), "kind": kind, "stats": stats},
    )
    return out, info


def impute(t: Table, cols: Sequence[str], strategy: str = "mean") -> OpResult:
    """Contextual TRANSFORM: fill nulls from whole-column statistics."""
    out = t.copy()
    fills = {}
    for c in cols:
        j = t.cid(c)
        x = t.data[:, j]
        valid = ~t.null[:, j]
        if strategy == "mean":
            fill = float(x[valid].mean()) if valid.any() else 0.0
        elif strategy == "median":
            fill = float(np.median(x[valid])) if valid.any() else 0.0
        elif strategy == "mode":
            if valid.any():
                vals, counts = np.unique(x[valid], return_counts=True)
                fill = float(vals[np.argmax(counts)])
            else:
                fill = 0.0
        else:
            raise ValueError(strategy)
        out.data[~valid, j] = fill
        out.null[:, j] = False
        fills[c] = fill
    info = CaptureInfo(
        op_name=f"impute:{strategy}",
        category=OpCategory.TRANSFORM,
        contextual=True,
        n_out=t.n_rows,
        n_in=[t.n_rows],
        attr_maps=[AttrMap(kind="identity")],
        params={"cols": list(cols), "strategy": strategy, "fills": fills},
    )
    return out, info


def discretize(t: Table, col: str, n_bins: int, kind: str = "uniform") -> OpResult:
    """TRANSFORM; quantile binning is contextual, uniform with fixed range is
    contextual too (range comes from the data) unless bounds are provided."""
    out = t.copy()
    j = t.cid(col)
    x = t.data[:, j]
    if kind == "uniform":
        lo, hi = float(x.min()), float(x.max())
        edges = np.linspace(lo, hi, n_bins + 1)[1:-1]
    elif kind == "quantile":
        edges = np.quantile(x, np.linspace(0, 1, n_bins + 1)[1:-1])
    else:
        raise ValueError(kind)
    out.data[:, j] = np.searchsorted(edges, x).astype(np.float32)
    info = CaptureInfo(
        op_name=f"discretize:{kind}",
        category=OpCategory.TRANSFORM,
        contextual=True,
        n_out=t.n_rows,
        n_in=[t.n_rows],
        attr_maps=[AttrMap(kind="identity")],
        params={"col": col, "edges": edges.tolist(), "kind": kind},
    )
    return out, info


# ---------------------------------------------------------------------------
# Vertical reduction (identity tensor; bitset attr map — paper Table VI)
# ---------------------------------------------------------------------------
def select_columns(t: Table, cols: Sequence[str]) -> OpResult:
    """Keep ``cols`` in their original relative order (bitset annotation) or
    arbitrary order (falls back to the paper's permutation-list annotation)."""
    keep_ids = [t.cid(c) for c in cols]
    order_preserved = keep_ids == sorted(keep_ids)
    out = t.take_cols(cols)
    bits = Bitset.from_indices(keep_ids, t.n_cols)
    amap = AttrMap(kind="vreduce", bitset=bits)
    if not order_preserved:
        amap.perm = np.asarray(keep_ids, dtype=np.int32)
    info = CaptureInfo(
        op_name="select_columns",
        category=OpCategory.VREDUCE,
        contextual=False,
        n_out=t.n_rows,
        n_in=[t.n_rows],
        attr_maps=[amap],
        params={"cols": list(cols)},
    )
    return out, info


def drop_columns(t: Table, cols: Sequence[str]) -> OpResult:
    keep = [c for c in t.columns if c not in set(cols)]
    out, info = select_columns(t, keep)
    info.op_name = "drop_columns"
    info.params = {"cols": list(cols)}
    return out, info


# ---------------------------------------------------------------------------
# Horizontal reduction (masking tensor; identity attr map)
# ---------------------------------------------------------------------------
def filter_rows(t: Table, mask: np.ndarray, op_name: str = "filter") -> OpResult:
    """Observation-based capture via preserved dataframe indices (§III-B)."""
    mask = np.asarray(mask, dtype=bool)
    kept = np.flatnonzero(mask)
    out = t.take_rows(kept, keep_index=True)
    info = CaptureInfo(
        op_name=op_name,
        category=OpCategory.HREDUCE,
        contextual=False,
        n_out=len(kept),
        n_in=[t.n_rows],
        kept_rows=kept.astype(np.int32),
        attr_maps=[AttrMap(kind="identity")],
        params={},
    )
    return out, info


def undersample(t: Table, frac: float, seed: int = 0) -> OpResult:
    rng = np.random.default_rng(seed)
    kept = np.sort(rng.choice(t.n_rows, size=max(1, int(t.n_rows * frac)), replace=False))
    mask = np.zeros(t.n_rows, dtype=bool)
    mask[kept] = True
    out, info = filter_rows(t, mask, op_name="undersample")
    info.params = {"frac": frac, "seed": seed}
    return out, info


# ---------------------------------------------------------------------------
# Vertical augmentation (identity tensor; bitset attr map — paper Table VI)
# ---------------------------------------------------------------------------
def onehot(t: Table, col: str, n_values: Optional[int] = None) -> OpResult:
    j = t.cid(col)
    x = t.data[:, j].astype(np.int64)
    contextual = n_values is None
    if n_values is None:
        n_values = int(x.max()) + 1 if len(x) else 1
    eye = np.zeros((t.n_rows, n_values), dtype=np.float32)
    valid = (x >= 0) & (x < n_values) & ~t.null[:, j]
    eye[np.arange(t.n_rows)[valid], x[valid]] = 1.0
    new_names = [f"{col}={v}" for v in range(n_values)]
    out = Table(
        columns=t.columns + new_names,
        data=np.concatenate([t.data, eye], axis=1),
        null=np.concatenate([t.null, np.zeros_like(eye, dtype=bool)], axis=1),
        index=t.index.copy(),
        vocab=dict(t.vocab),
    )
    m = t.n_cols
    # paper's single-bitset encoding: source input attrs ∪ new output attrs
    bits = Bitset.from_indices([j] + list(range(m, m + n_values)), m + n_values)
    info = CaptureInfo(
        op_name="onehot",
        category=OpCategory.VAUGMENT,
        contextual=contextual,
        n_out=t.n_rows,
        n_in=[t.n_rows],
        attr_maps=[AttrMap(kind="vaugment", bitset=bits, m=m)],
        params={"col": col, "n_values": n_values},
    )
    return out, info


def string_indexer(t: Table, col: str) -> OpResult:
    """Adds ``col#idx`` = dense rank of the value (contextual: needs domain)."""
    j = t.cid(col)
    x = t.data[:, j]
    vals = np.unique(x[~t.null[:, j]])
    codes = np.searchsorted(vals, x).astype(np.float32)
    out = Table(
        columns=t.columns + [f"{col}#idx"],
        data=np.concatenate([t.data, codes[:, None]], axis=1),
        null=np.concatenate([t.null, t.null[:, j : j + 1]], axis=1),
        index=t.index.copy(),
        vocab=dict(t.vocab),
    )
    m = t.n_cols
    bits = Bitset.from_indices([j, m], m + 1)
    info = CaptureInfo(
        op_name="string_indexer",
        category=OpCategory.VAUGMENT,
        contextual=True,
        n_out=t.n_rows,
        n_in=[t.n_rows],
        attr_maps=[AttrMap(kind="vaugment", bitset=bits, m=m)],
        params={"col": col, "domain": vals.tolist()},
    )
    return out, info


def space_transform(t: Table, cols: Sequence[str], proj: np.ndarray, prefix: str = "pc") -> OpResult:
    """Linear feature map (PCA-style) onto ``proj.shape[1]`` new attributes.
    Localized when the projection matrix is given (fixed params)."""
    ids = [t.cid(c) for c in cols]
    proj = np.asarray(proj, dtype=np.float32)
    newvals = t.data[:, ids] @ proj
    names = [f"{prefix}{i}" for i in range(proj.shape[1])]
    out = Table(
        columns=t.columns + names,
        data=np.concatenate([t.data, newvals], axis=1),
        null=np.concatenate([t.null, np.zeros_like(newvals, dtype=bool)], axis=1),
        index=t.index.copy(),
        vocab=dict(t.vocab),
    )
    m = t.n_cols
    bits = Bitset.from_indices(ids + list(range(m, m + proj.shape[1])), m + proj.shape[1])
    info = CaptureInfo(
        op_name="space_transform",
        category=OpCategory.VAUGMENT,
        contextual=False,
        n_out=t.n_rows,
        n_in=[t.n_rows],
        attr_maps=[AttrMap(kind="vaugment", bitset=bits, m=m)],
        params={"cols": list(cols), "proj": proj},
    )
    return out, info


# ---------------------------------------------------------------------------
# Horizontal augmentation (src-mapped tensor; identity attr map)
# ---------------------------------------------------------------------------
def oversample(t: Table, frac: float, seed: int = 0, noise: float = 0.0) -> OpResult:
    """Appends ``frac * n`` duplicated (optionally jittered) rows.  The paper
    (§III-A e) keeps the output->source correspondence whenever establishable —
    here it always is, by construction."""
    rng = np.random.default_rng(seed)
    n_new = max(1, int(t.n_rows * frac))
    picks = rng.integers(0, t.n_rows, size=n_new)
    new_data = t.data[picks].copy()
    if noise > 0:
        new_data += rng.normal(0.0, noise, size=new_data.shape).astype(np.float32)
    out = Table(
        columns=list(t.columns),
        data=np.concatenate([t.data, new_data], axis=0),
        null=np.concatenate([t.null, t.null[picks]], axis=0),
        index=np.concatenate([t.index, t.index.max() + 1 + np.arange(n_new, dtype=np.int64)]),
        vocab=dict(t.vocab),
    )
    src = np.concatenate([np.arange(t.n_rows, dtype=np.int32), picks.astype(np.int32)])
    info = CaptureInfo(
        op_name="oversample",
        category=OpCategory.HAUGMENT,
        contextual=False,
        n_out=out.n_rows,
        n_in=[t.n_rows],
        src_rows=src,
        attr_maps=[AttrMap(kind="identity")],
        params={"frac": frac, "seed": seed, "noise": noise},
    )
    return out, info


# ---------------------------------------------------------------------------
# Join (order-3 tensor; two bitsets + permutation lists — paper Table VI)
# ---------------------------------------------------------------------------
def join(left: Table, right: Table, on: str, how: str = "inner", max_pairs: Optional[int] = None) -> OpResult:
    """Sort-merge equi-join with Pandas-merge bag semantics.

    ACTIVE capture (paper §III-B / §V): the match is computed over row-id
    vectors threaded through the sort — the produced (left_row, right_row)
    pairs ARE the provenance; no post-hoc content comparison ever happens.
    """
    lk = left.col(on)
    rk = right.col(on)
    # sort right once; for each left key find its match range
    r_order = np.argsort(rk, kind="stable").astype(np.int64)
    rk_sorted = rk[r_order]
    lo = np.searchsorted(rk_sorted, lk, side="left")
    hi = np.searchsorted(rk_sorted, lk, side="right")
    counts = hi - lo
    l_rows = np.repeat(np.arange(left.n_rows, dtype=np.int64), counts)
    offsets = np.concatenate(([0], np.cumsum(counts)[:-1]))
    flat = np.repeat(lo - offsets, counts) + np.arange(counts.sum(), dtype=np.int64) \
        if counts.sum() else np.zeros(0, dtype=np.int64)
    r_rows = r_order[flat.astype(np.int64)] if counts.sum() else np.zeros(0, dtype=np.int64)

    pairs = [np.stack([l_rows, r_rows], axis=1)] if counts.sum() else [np.zeros((0, 2), np.int64)]
    if how in ("left", "outer"):
        dangling_l = np.flatnonzero(counts == 0)
        pairs.append(np.stack([dangling_l, np.full(len(dangling_l), -1, np.int64)], axis=1))
    if how in ("right", "outer"):
        matched_r = np.zeros(right.n_rows, dtype=bool)
        if counts.sum():
            matched_r[r_rows] = True
        dangling_r = np.flatnonzero(~matched_r)
        pairs.append(np.stack([np.full(len(dangling_r), -1, np.int64), dangling_r], axis=1))
    pairs = np.concatenate(pairs, axis=0)
    if max_pairs is not None and len(pairs) > max_pairs:
        pairs = pairs[:max_pairs]

    # assemble output: key, left non-key cols, right non-key cols
    l_cols = [c for c in left.columns if c != on]
    r_cols = [c for c in right.columns if c != on]
    out_names = [on] + [f"{c}_l" if c in r_cols else c for c in l_cols] \
        + [f"{c}_r" if c in l_cols else c for c in r_cols]
    n_out_attrs = 1 + len(l_cols) + len(r_cols)
    n_out = len(pairs)
    data = np.zeros((n_out, n_out_attrs), dtype=np.float32)
    null = np.ones((n_out, n_out_attrs), dtype=bool)
    has_l = pairs[:, 0] >= 0
    has_r = pairs[:, 1] >= 0
    li = np.where(has_l, pairs[:, 0], 0)
    ri = np.where(has_r, pairs[:, 1], 0)
    # key (from whichever side exists)
    data[:, 0] = np.where(has_l, left.data[li, left.cid(on)], right.data[ri, right.cid(on)])
    null[:, 0] = np.where(has_l, left.null[li, left.cid(on)], right.null[ri, right.cid(on)])
    for a, c in enumerate(l_cols):
        j = left.cid(c)
        data[:, 1 + a] = np.where(has_l, left.data[li, j], 0.0)
        null[:, 1 + a] = np.where(has_l, left.null[li, j], True)
    for a, c in enumerate(r_cols):
        j = right.cid(c)
        data[:, 1 + len(l_cols) + a] = np.where(has_r, right.data[ri, j], 0.0)
        null[:, 1 + len(l_cols) + a] = np.where(has_r, right.null[ri, j], True)

    out = Table(
        columns=out_names,
        data=data,
        null=null,
        index=np.arange(n_out, dtype=np.int64),
        vocab={**{c: v for c, v in right.vocab.items()}, **{c: v for c, v in left.vocab.items()}},
    )

    # paper Table VI: one bitset per input over OUTPUT attr positions
    bits_l = Bitset.from_indices([0] + list(range(1, 1 + len(l_cols))), n_out_attrs)
    bits_r = Bitset.from_indices([0] + list(range(1 + len(l_cols), n_out_attrs)), n_out_attrs)
    # explicit permutation lists (order-changing fallback): out attr -> in attr
    perm_l = np.full(n_out_attrs, -1, dtype=np.int32)
    perm_l[0] = left.cid(on)
    for a, c in enumerate(l_cols):
        perm_l[1 + a] = left.cid(c)
    perm_r = np.full(n_out_attrs, -1, dtype=np.int32)
    perm_r[0] = right.cid(on)
    for a, c in enumerate(r_cols):
        perm_r[1 + len(l_cols) + a] = right.cid(c)

    info = CaptureInfo(
        op_name=f"join:{how}",
        category=OpCategory.JOIN,
        contextual=False,
        n_out=n_out,
        n_in=[left.n_rows, right.n_rows],
        join_pairs=pairs.astype(np.int32),
        attr_maps=[
            AttrMap(kind="join", bitset=bits_l, perm=perm_l),
            AttrMap(kind="join", bitset=bits_r, perm=perm_r),
        ],
        params={"on": on, "how": how},
    )
    return out, info


# ---------------------------------------------------------------------------
# Append (two block-diagonal tensors; two bitsets — paper §III-A g)
# ---------------------------------------------------------------------------
def append(left: Table, right: Table) -> OpResult:
    """Outer-union: result schema = left cols ∪ right cols, null-extended."""
    out_names = list(left.columns) + [c for c in right.columns if c not in left.columns]
    n_out = left.n_rows + right.n_rows
    data = np.zeros((n_out, len(out_names)), dtype=np.float32)
    null = np.ones((n_out, len(out_names)), dtype=bool)
    for a, c in enumerate(out_names):
        if c in left.columns:
            data[: left.n_rows, a] = left.col(c)
            null[: left.n_rows, a] = left.col_null(c)
        if c in right.columns:
            data[left.n_rows :, a] = right.col(c)
            null[left.n_rows :, a] = right.col_null(c)
    out = Table(
        columns=out_names,
        data=data,
        null=null,
        index=np.arange(n_out, dtype=np.int64),
        vocab={**right.vocab, **left.vocab},
    )
    perm_l = np.full(len(out_names), -1, dtype=np.int32)
    perm_r = np.full(len(out_names), -1, dtype=np.int32)
    for a, c in enumerate(out_names):
        if c in left.columns:
            perm_l[a] = left.cid(c)
        if c in right.columns:
            perm_r[a] = right.cid(c)
    bits_l = Bitset.from_bits(perm_l >= 0)
    bits_r = Bitset.from_bits(perm_r >= 0)
    info = CaptureInfo(
        op_name="append",
        category=OpCategory.APPEND,
        contextual=False,
        n_out=n_out,
        n_in=[left.n_rows, right.n_rows],
        attr_maps=[
            AttrMap(kind="join", bitset=bits_l, perm=perm_l),
            AttrMap(kind="join", bitset=bits_r, perm=perm_r),
        ],
        params={},
    )
    return out, info
