"""The paper's three use-case pipelines + TPC-DI-style synthetic join data.

Shapes follow Table VIII exactly:

  German  4 ops  1000  rows  21 attrs ->  1000 rows  60 attrs
  Compas  7 ops  7214  rows  53 attrs ->  6907 rows   8 attrs
  Census  5 ops  32561 rows  15 attrs -> 32561 rows 104 attrs

Data content is synthetic (the originals are external downloads; offline
container), but the OPERATION MIX matches the published pipelines: impute /
normalize / onehot for German-credit-style categorical expansion, filtering +
column pruning for Compas, heavy one-hot expansion for Census.  The TPC-DI
generator reproduces Table XI's join cardinalities per scale factor.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import numpy as np

from repro.core.pipeline import ProvenanceIndex
from repro.dataprep.table import Table
from repro.dataprep.tracked import TrackedTable, track

__all__ = [
    "make_german",
    "make_compas",
    "make_census",
    "run_german",
    "run_compas",
    "run_census",
    "make_tpcdi_join_inputs",
    "USECASES",
    "TPCDI_SCALES",
]


def _rand_table(n_rows: int, n_cols: int, n_cat: int, seed: int, null_frac: float = 0.02) -> Table:
    rng = np.random.default_rng(seed)
    cols = {}
    nulls = {}
    for j in range(n_cols):
        name = f"a{j}"
        if j < n_cat:
            cols[name] = rng.integers(0, 4 + j % 5, size=n_rows).astype(np.float32)
        else:
            cols[name] = rng.normal(0, 1 + j % 3, size=n_rows).astype(np.float32)
        nulls[name] = rng.random(n_rows) < null_frac
    t = Table.from_columns(cols, null=nulls)
    return t


# ---------------------------------------------------------------------------
# German credit: 1000 x 21 -> 1000 x 60 in 4 ops
# ---------------------------------------------------------------------------
def make_german(seed: int = 0) -> Table:
    return _rand_table(1000, 21, n_cat=13, seed=seed)


def run_german(index: ProvenanceIndex, t: Table) -> TrackedTable:
    d = track(t, index, "german_src")
    d = d.impute([f"a{j}" for j in range(13, 21)], strategy="mean")         # 1
    d = d.normalize([f"a{j}" for j in range(13, 21)], kind="zscore")        # 2
    d = d.onehot("a0", n_values=9)                                          # 3: 21+9=30
    d = d.onehot("a1", n_values=30)                                         # 4: 30+30=60
    return d.mark_sink()


# ---------------------------------------------------------------------------
# Compas: 7214 x 53 -> 6907 x 8 in 7 ops
# ---------------------------------------------------------------------------
def make_compas(seed: int = 1) -> Table:
    return _rand_table(7214, 53, n_cat=20, seed=seed)


def run_compas(index: ProvenanceIndex, t: Table) -> TrackedTable:
    d = track(t, index, "compas_src")
    d = d.impute(["a25", "a30"], strategy="median")                          # 1
    # keep the top-6907 rows by a21 (value-driven threshold, exact Table VIII count)
    vals = np.asarray(d.table.col("a21"))
    thresh = np.partition(vals, len(vals) - 6907)[len(vals) - 6907]
    kept = np.flatnonzero(vals >= thresh)[:6907]
    m2 = np.zeros(len(vals), dtype=bool)
    m2[kept] = True
    d = d.filter_rows(m2, op_name="filter:days_b_screening")                 # 2 -> 6907 rows
    d = d.value_transform("a22", "clip", lo=-3.0, hi=3.0)                    # 3
    d = d.binarize("a23", threshold=0.0)                                     # 4
    d = d.discretize("a24", n_bins=4, kind="quantile")                       # 5
    d = d.normalize(["a22"], kind="minmax")                                  # 6
    d = d.select_columns([f"a{j}" for j in (0, 5, 21, 22, 23, 24, 25, 30)])  # 7 -> 8 attrs
    return d.mark_sink()


# ---------------------------------------------------------------------------
# Census (adult): 32561 x 15 -> 32561 x 104 in 5 ops
# ---------------------------------------------------------------------------
def make_census(seed: int = 2) -> Table:
    return _rand_table(32561, 15, n_cat=9, seed=seed)


def run_census(index: ProvenanceIndex, t: Table) -> TrackedTable:
    d = track(t, index, "census_src")
    d = d.impute([f"a{j}" for j in range(9, 15)], strategy="mean")           # 1
    d = d.normalize([f"a{j}" for j in range(9, 15)], kind="zscore")          # 2
    d = d.onehot("a0", n_values=9)                                           # 3: 15+9=24
    d = d.onehot("a1", n_values=16)                                          # 4: 24+16=40
    d = d.onehot("a2", n_values=64)                                          # 5: 40+64=104
    return d.mark_sink()


USECASES: Dict[str, Tuple[Callable[[int], Table], Callable]] = {
    "german": (make_german, run_german),
    "compas": (make_compas, run_compas),
    "census": (make_census, run_census),
}


# ---------------------------------------------------------------------------
# TPC-DI-like synthetic join inputs (Table XI cardinalities per scale factor)
# ---------------------------------------------------------------------------
TPCDI_SCALES = {
    3: (362342, 390978),
    5: (602956, 650412),
    9: (1085239, 1171107),
    15: (1807703, 1951236),
    20: (2411006, 2601648),
}


def make_tpcdi_join_inputs(scale: int, seed: int = 7, n_attrs: int = 8) -> Tuple[Table, Table]:
    """Two key-sharing tables whose inner join has ~|left| matches (each left
    row matches one right row, mirroring the DimTrade/DimSecurity-style
    surrogate-key joins TPC-DI performs)."""
    n_l, n_r = TPCDI_SCALES[scale]
    rng = np.random.default_rng(seed)
    # left keys: subset of right key space (1:1 matches, some dangling rights)
    right_keys = np.arange(n_r, dtype=np.float32)
    left_keys = rng.choice(n_r, size=n_l, replace=False).astype(np.float32) \
        if n_l <= n_r else rng.integers(0, n_r, size=n_l).astype(np.float32)
    lcols = {"key": left_keys}
    rcols = {"key": right_keys}
    for j in range(n_attrs - 1):
        lcols[f"l{j}"] = rng.normal(size=n_l).astype(np.float32)
        rcols[f"r{j}"] = rng.normal(size=n_r).astype(np.float32)
    return Table.from_columns(lcols), Table.from_columns(rcols)
