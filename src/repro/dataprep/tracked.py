"""TrackedTable — the decorator-pattern capture front-end (paper §V).

The paper wraps ``pandas.DataFrame`` in a container that proxies operations
and captures provenance as a side effect.  Here the substrate is
:class:`repro.dataprep.table.Table`; every data-prep op from
:mod:`repro.dataprep.ops` is exposed as a method that (1) executes the op,
(2) hands its CaptureInfo to the shared :class:`ProvenanceIndex`, and
(3) returns a new TrackedTable for the output dataset.  The user writes
pipeline code exactly as they would untracked — capture is automatic.
"""
from __future__ import annotations

import itertools
from typing import Optional, Sequence

import numpy as np

from repro.core.opcat import CaptureInfo
from repro.core.pipeline import ProvenanceIndex
from repro.dataprep import ops as P
from repro.dataprep.table import Table

__all__ = ["TrackedTable", "track"]

_counter = itertools.count()


def _fresh_id(stem: str) -> str:
    return f"{stem}#{next(_counter)}"


class TrackedTable:
    """Decorator around Table: proxies reads, intercepts data-prep ops."""

    def __init__(self, table: Table, index: ProvenanceIndex, dataset_id: str):
        self.table = table
        self.index = index
        self.dataset_id = dataset_id

    # ---- transparent proxying of reads --------------------------------------
    def __getattr__(self, name):
        return getattr(self.table, name)

    def __len__(self) -> int:
        return self.table.n_rows

    # ---- capture plumbing ----------------------------------------------------
    def _emit(
        self,
        out: Table,
        info: CaptureInfo,
        inputs: Sequence["TrackedTable"],
        keep_output: bool = False,
        out_id: Optional[str] = None,
    ) -> "TrackedTable":
        out_id = out_id or _fresh_id(info.op_name.split(":")[0])
        self.index.record(
            [t.dataset_id for t in inputs],
            out_id,
            out,
            info,
            keep_output=keep_output,
            input_tables=[t.table for t in inputs],
        )
        return TrackedTable(out, self.index, out_id)

    # ---- the intercepted operations (paper Table I) ---------------------------
    def value_transform(self, col, fn, **kw):
        out, info = P.value_transform(self.table, col, fn, **kw)
        return self._emit(out, info, [self])

    def binarize(self, col, threshold):
        out, info = P.binarize(self.table, col, threshold)
        return self._emit(out, info, [self])

    def normalize(self, cols, kind="zscore"):
        out, info = P.normalize(self.table, cols, kind)
        return self._emit(out, info, [self])

    def impute(self, cols, strategy="mean"):
        out, info = P.impute(self.table, cols, strategy)
        return self._emit(out, info, [self])

    def discretize(self, col, n_bins, kind="uniform"):
        out, info = P.discretize(self.table, col, n_bins, kind)
        return self._emit(out, info, [self])

    def select_columns(self, cols):
        out, info = P.select_columns(self.table, cols)
        return self._emit(out, info, [self])

    def drop_columns(self, cols):
        out, info = P.drop_columns(self.table, cols)
        return self._emit(out, info, [self])

    def filter_rows(self, mask, op_name="filter"):
        out, info = P.filter_rows(self.table, mask, op_name)
        return self._emit(out, info, [self])

    def undersample(self, frac, seed=0):
        out, info = P.undersample(self.table, frac, seed)
        return self._emit(out, info, [self])

    def onehot(self, col, n_values=None):
        out, info = P.onehot(self.table, col, n_values)
        return self._emit(out, info, [self])

    def string_indexer(self, col):
        out, info = P.string_indexer(self.table, col)
        return self._emit(out, info, [self])

    def space_transform(self, cols, proj, prefix="pc"):
        out, info = P.space_transform(self.table, cols, proj, prefix)
        return self._emit(out, info, [self])

    def oversample(self, frac, seed=0, noise=0.0):
        out, info = P.oversample(self.table, frac, seed, noise)
        return self._emit(out, info, [self])

    def join(self, other: "TrackedTable", on, how="inner"):
        out, info = P.join(self.table, other.table, on, how)
        return self._emit(out, info, [self, other])

    def append(self, other: "TrackedTable"):
        out, info = P.append(self.table, other.table)
        return self._emit(out, info, [self, other])

    def mark_sink(self) -> "TrackedTable":
        """Flag this dataset as a pipeline output (always materialized)."""
        rec = self.index.datasets[self.dataset_id]
        rec.table = self.table
        rec.is_sink = True
        return self


def track(table: Table, index: ProvenanceIndex, dataset_id: Optional[str] = None) -> TrackedTable:
    """Register ``table`` as a pipeline SOURCE and wrap it for tracking."""
    dataset_id = dataset_id or _fresh_id("src")
    index.add_source(dataset_id, table)
    return TrackedTable(table, index, dataset_id)
