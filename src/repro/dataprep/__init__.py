"""Data-preparation substrate instrumented by TensProv (paper Table I ops)."""
from repro.dataprep.table import Table
