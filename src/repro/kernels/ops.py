"""Jit'd public wrappers around the Pallas kernels.

These handle padding to hardware-aligned block shapes, choose interpret mode
automatically off-TPU (this container is CPU-only; TPU v5e is the TARGET),
and unpad results.  All call sites in :mod:`repro.core` go through here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bitmatmul import bitmatmul_pallas
from repro.kernels.lineage_gather import lineage_gather_pallas
from repro.kernels.bitset_rank import bitset_rank_pallas
from repro.kernels.batched_walk import batched_walk_pallas
from repro.kernels import ref

__all__ = [
    "bitmatmul",
    "bitplane_probe",
    "lineage_gather",
    "bitset_rank",
    "batched_walk",
    "batched_walk_unfused",
    "on_tpu",
    "launch_counts",
    "reset_launch_counts",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# -- launch accounting --------------------------------------------------------
# Every public kernel entry counts ONE device dispatch (the Pallas launch on
# TPU, the equivalent jit'd oracle call elsewhere).  bench_compose_roofline
# asserts the fused walk's K×3 -> 1 launch reduction off these counters.
_LAUNCHES: dict = {}


def _note_launch(name: str) -> None:
    _LAUNCHES[name] = _LAUNCHES.get(name, 0) + 1


def launch_counts() -> dict:
    """{kernel entry: dispatch count} since the last reset."""
    return dict(_LAUNCHES)


def reset_launch_counts() -> None:
    _LAUNCHES.clear()


def _pad_to(x: jax.Array, axis: int, multiple: int, value=0) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def bitmatmul(
    a_bits,
    b_bits,
    *,
    block_m: int = 8,
    block_nw: int = 128,
    block_k: int = 256,
    interpret: bool | None = None,
    use_pallas: bool | None = True,
):
    """(OR,AND)-compose packed relations: (M, K/32) x (K, N/32) -> (M, N/32).

    ``use_pallas=False`` falls back to the jnp oracle (used for very small
    relations where kernel launch overhead dominates, and on hosts where
    interpret-mode cost would be prohibitive for large shapes).
    ``use_pallas=None`` resolves automatically — the cost model's
    kernel-launch guard: the Pallas kernel on TPU, the oracle elsewhere
    (interpret-mode emulation is never the cheaper backend on host).
    """
    _note_launch("bitmatmul")
    a_bits = jnp.asarray(a_bits, dtype=jnp.uint32)
    b_bits = jnp.asarray(b_bits, dtype=jnp.uint32)
    m, kw = a_bits.shape
    k, nw = b_bits.shape
    if use_pallas is None:
        use_pallas = on_tpu()
    if not ((kw - 1) * 32 < k <= kw * 32):
        raise ValueError(f"contraction mismatch: A packs {kw * 32} cols, B has {k} rows")
    # Zero-pad B's contraction rows up to A's packed width (zero rows are inert).
    b_bits = _pad_to(b_bits, 0, 32) if k % 32 else b_bits
    if interpret is None:
        interpret = not on_tpu()
    if not use_pallas:
        return ref.bitmatmul_ref(a_bits, b_bits)

    # Pad every dim to its block multiple (zero bits contribute nothing).
    a_p = _pad_to(_pad_to(a_bits, 0, block_m), 1, block_k // 32)
    b_p = _pad_to(_pad_to(b_bits, 0, block_k), 1, block_nw)
    out = bitmatmul_pallas(
        a_p,
        b_p,
        block_m=block_m,
        block_nw=block_nw,
        block_k=block_k,
        interpret=interpret,
    )
    return out[:m, :nw]


def bitplane_probe(mask_bits, plane_bits, *, use_pallas: bool | None = True, **kw):
    """Batched lineage probe of a composed relation (the hop-cache hot path).

    ``mask_bits`` (B, ⌈K/32⌉) packs B row-selector sets; ``plane_bits``
    (K, ⌈N/32⌉) is a composed relation bitplane.  Row b of the result packs
    the union of plane rows selected by probe b — the same (OR,AND)
    contraction as :func:`bitmatmul`, so it shares the Pallas kernel.
    """
    return bitmatmul(mask_bits, plane_bits, use_pallas=use_pallas, **kw)


def lineage_gather(
    row_ptr,
    col_idx,
    queries,
    *,
    max_deg: int,
    block_q: int = 128,
    interpret: bool | None = None,
    use_pallas: bool | None = True,
):
    """Batched CSR probe -> (Q, max_deg) padded neighbor table.

    ``use_pallas=None`` applies the same kernel-launch guard as
    :func:`bitmatmul`: Pallas on TPU, the jnp oracle elsewhere.
    """
    _note_launch("lineage_gather")
    row_ptr = jnp.asarray(row_ptr, dtype=jnp.int32)
    col_idx = jnp.asarray(col_idx, dtype=jnp.int32)
    queries = jnp.asarray(queries, dtype=jnp.int32)
    if use_pallas is None:
        use_pallas = on_tpu()
    if interpret is None:
        interpret = not on_tpu()
    q = queries.shape[0]
    md = max(int(max_deg), 1)
    # Sentinel-pad col_idx so the dynamic slice never reads OOB.
    col_p = jnp.concatenate([col_idx, jnp.full((md,), -1, jnp.int32)])
    if not use_pallas:
        return ref.lineage_gather_ref(queries, row_ptr, col_p, max_deg=md)
    md_pad = -(-md // 128) * 128 if md > 8 else md  # lane-align when big
    col_p = jnp.concatenate([col_idx, jnp.full((md_pad,), -1, jnp.int32)])
    q_p = _pad_to(queries, 0, block_q)
    out = lineage_gather_pallas(
        q_p, row_ptr, col_p, max_deg=md_pad, block_q=block_q, interpret=interpret
    )
    return out[:q, :md]


def bitset_rank(
    words,
    positions,
    *,
    block_q: int = 128,
    interpret: bool | None = None,
    use_pallas: bool | None = True,
):
    """Batched inclusive rank over one packed bitset.

    ``use_pallas=None`` applies the same kernel-launch guard as
    :func:`bitmatmul`: Pallas on TPU, the jnp oracle elsewhere.
    """
    _note_launch("bitset_rank")
    words = jnp.asarray(words, dtype=jnp.uint32)
    positions = jnp.asarray(positions, dtype=jnp.int32)
    if use_pallas is None:
        use_pallas = on_tpu()
    if interpret is None:
        interpret = not on_tpu()
    if not use_pallas:
        return ref.bitset_rank_ref(words, positions)
    q = positions.shape[0]
    # -1 pads resolve to rank 0 in-kernel via the pos<0 guard.
    p_p = _pad_to(positions, 0, block_q, value=0)
    out = bitset_rank_pallas(words, p_p, block_q=block_q, interpret=interpret)
    return out[:q]


# ---------------------------------------------------------------------------
# Fused K-hop batched walk (ROADMAP item 4)
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("n_hops",))
def _batched_walk_oracle(mask_bits, *planes, n_hops: int):
    # one jit'd fold over the whole chain == one device dispatch
    return ref.batched_walk_ref(mask_bits, planes)


def _check_walk_chain(mask_bits, planes) -> None:
    kw = mask_bits.shape[1]
    for j, plane in enumerate(planes):
        rows = plane.shape[0]
        if not ((kw - 1) * 32 < rows <= kw * 32):
            raise ValueError(
                f"hop {j}: frontier packs {kw * 32} cols, plane has {rows} rows"
            )
        kw = plane.shape[1]


def batched_walk(
    mask_bits,
    planes,
    *,
    block_b: int = 8,
    block_k: int = 256,
    interpret: bool | None = None,
    use_pallas: bool | None = None,
):
    """K-hop batched record probe in ONE kernel launch.

    ``mask_bits`` (B, ⌈n_0/32⌉) packs B probe sets over the chain's entry
    dim; ``planes[j]`` is hop j's packed (n_j, ⌈n_{j+1}/32⌉) relation
    bitplane.  Returns ``(out_bits (B, ⌈n_K/32⌉) uint32, counts (K, B)
    int32)`` — the final frontier plus each hop's per-probe frontier size
    (the rank term the per-hop path pays a separate ``bitset_rank`` for).

    ``use_pallas=None`` (the default) applies the kernel-launch guard: the
    fused Pallas kernel on TPU, the jit'd jnp oracle (still one dispatch)
    elsewhere.  For the Pallas path every hop dim is zero-padded to one
    common square dim (inert under (OR, AND)) and the planes stack into a
    single streamed operand; see :mod:`repro.kernels.batched_walk`.
    """
    _note_launch("batched_walk")
    mask_bits = jnp.asarray(mask_bits, dtype=jnp.uint32)
    planes = [jnp.asarray(p, dtype=jnp.uint32) for p in planes]
    if not planes:
        raise ValueError("batched_walk needs at least one hop")
    _check_walk_chain(mask_bits, planes)
    if use_pallas is None:
        use_pallas = on_tpu()
    if interpret is None:
        interpret = not on_tpu()
    b = mask_bits.shape[0]
    k = len(planes)
    out_w = planes[-1].shape[1]
    if not use_pallas:
        out, counts = _batched_walk_oracle(mask_bits, *planes, n_hops=k)
        return out, counts

    # One common padded dim: every hop's rows AND packed cols fit inside it.
    n_pad = 32  # at least one word
    for p in planes:
        n_pad = max(n_pad, p.shape[0], p.shape[1] * 32)
    n_pad = max(n_pad, mask_bits.shape[1] * 32)
    n_pad = -(-n_pad // block_k) * block_k
    nw = n_pad // 32
    mask_p = _pad_to(_pad_to(mask_bits, 0, block_b), 1, nw)
    stacked = jnp.zeros((k, n_pad, nw), dtype=jnp.uint32)
    for j, p in enumerate(planes):
        stacked = stacked.at[j, : p.shape[0], : p.shape[1]].set(p)
    out_p, counts_p = batched_walk_pallas(
        mask_p, stacked, block_b=block_b, block_k=block_k, interpret=interpret
    )
    return out_p[:b, :out_w], counts_p[:, :b]


def batched_walk_unfused(
    mask_bits,
    planes,
    *,
    max_deg: int | None = None,
    interpret: bool | None = None,
    use_pallas: bool | None = None,
):
    """The per-hop baseline the fused kernel replaces: K×3 launches.

    Per hop: :func:`bitplane_probe` (select-OR contraction),
    :func:`bitset_rank` over the flattened frontier bitset (per-probe
    frontier sizes as rank differences at row boundaries), and
    :func:`lineage_gather` materializing the frontier's padded neighbor
    table from a host-rebuilt CSR — with the mask stack round-tripping
    through the host between every launch, which is exactly the traffic
    the fused kernel keeps resident in VMEM.  Returns the same
    ``(out_bits, counts)`` as :func:`batched_walk` (byte-identical).
    """
    cur = np.asarray(jnp.asarray(mask_bits, dtype=jnp.uint32))
    b = cur.shape[0]
    all_counts = []
    for plane in planes:
        cur = np.asarray(
            bitplane_probe(cur, plane, use_pallas=use_pallas,
                           interpret=interpret)
        )
        w = cur.shape[1]
        ends = np.arange(1, b + 1, dtype=np.int32) * (w * 32) - 1
        ranks = np.asarray(
            bitset_rank(cur.reshape(-1), ends, use_pallas=use_pallas,
                        interpret=interpret)
        )
        counts = np.diff(np.concatenate([[0], ranks])).astype(np.int32)
        all_counts.append(counts)
        # host-side CSR rebuild of the frontier — the per-hop tax the fused
        # kernel's resident mask avoids entirely
        row_ptr = np.zeros(b + 1, dtype=np.int32)
        np.cumsum(counts, out=row_ptr[1:])
        col_idx = np.concatenate(
            [np.flatnonzero(ref.unpack_bits(cur[i : i + 1], w * 32)[0])
             for i in range(b)]
        ).astype(np.int32) if row_ptr[-1] else np.zeros(0, dtype=np.int32)
        md = max_deg if max_deg is not None else max(int(counts.max()), 1)
        lineage_gather(row_ptr, col_idx, np.arange(b, dtype=np.int32),
                       max_deg=md, use_pallas=use_pallas, interpret=interpret)
    return jnp.asarray(cur), jnp.asarray(np.stack(all_counts, axis=0))
