"""Jit'd public wrappers around the Pallas kernels.

These handle padding to hardware-aligned block shapes, choose interpret mode
automatically off-TPU (this container is CPU-only; TPU v5e is the TARGET),
and unpad results.  All call sites in :mod:`repro.core` go through here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bitmatmul import bitmatmul_pallas
from repro.kernels.lineage_gather import lineage_gather_pallas
from repro.kernels.bitset_rank import bitset_rank_pallas
from repro.kernels import ref

__all__ = ["bitmatmul", "bitplane_probe", "lineage_gather", "bitset_rank", "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, multiple: int, value=0) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def bitmatmul(
    a_bits,
    b_bits,
    *,
    block_m: int = 8,
    block_nw: int = 128,
    block_k: int = 256,
    interpret: bool | None = None,
    use_pallas: bool | None = True,
):
    """(OR,AND)-compose packed relations: (M, K/32) x (K, N/32) -> (M, N/32).

    ``use_pallas=False`` falls back to the jnp oracle (used for very small
    relations where kernel launch overhead dominates, and on hosts where
    interpret-mode cost would be prohibitive for large shapes).
    ``use_pallas=None`` resolves automatically — the cost model's
    kernel-launch guard: the Pallas kernel on TPU, the oracle elsewhere
    (interpret-mode emulation is never the cheaper backend on host).
    """
    a_bits = jnp.asarray(a_bits, dtype=jnp.uint32)
    b_bits = jnp.asarray(b_bits, dtype=jnp.uint32)
    m, kw = a_bits.shape
    k, nw = b_bits.shape
    if use_pallas is None:
        use_pallas = on_tpu()
    if not ((kw - 1) * 32 < k <= kw * 32):
        raise ValueError(f"contraction mismatch: A packs {kw * 32} cols, B has {k} rows")
    # Zero-pad B's contraction rows up to A's packed width (zero rows are inert).
    b_bits = _pad_to(b_bits, 0, 32) if k % 32 else b_bits
    if interpret is None:
        interpret = not on_tpu()
    if not use_pallas:
        return ref.bitmatmul_ref(a_bits, b_bits)

    # Pad every dim to its block multiple (zero bits contribute nothing).
    a_p = _pad_to(_pad_to(a_bits, 0, block_m), 1, block_k // 32)
    b_p = _pad_to(_pad_to(b_bits, 0, block_k), 1, block_nw)
    out = bitmatmul_pallas(
        a_p,
        b_p,
        block_m=block_m,
        block_nw=block_nw,
        block_k=block_k,
        interpret=interpret,
    )
    return out[:m, :nw]


def bitplane_probe(mask_bits, plane_bits, *, use_pallas: bool | None = True, **kw):
    """Batched lineage probe of a composed relation (the hop-cache hot path).

    ``mask_bits`` (B, ⌈K/32⌉) packs B row-selector sets; ``plane_bits``
    (K, ⌈N/32⌉) is a composed relation bitplane.  Row b of the result packs
    the union of plane rows selected by probe b — the same (OR,AND)
    contraction as :func:`bitmatmul`, so it shares the Pallas kernel.
    """
    return bitmatmul(mask_bits, plane_bits, use_pallas=use_pallas, **kw)


def lineage_gather(
    row_ptr,
    col_idx,
    queries,
    *,
    max_deg: int,
    block_q: int = 128,
    interpret: bool | None = None,
    use_pallas: bool = True,
):
    """Batched CSR probe -> (Q, max_deg) padded neighbor table."""
    row_ptr = jnp.asarray(row_ptr, dtype=jnp.int32)
    col_idx = jnp.asarray(col_idx, dtype=jnp.int32)
    queries = jnp.asarray(queries, dtype=jnp.int32)
    if interpret is None:
        interpret = not on_tpu()
    q = queries.shape[0]
    md = max(int(max_deg), 1)
    # Sentinel-pad col_idx so the dynamic slice never reads OOB.
    col_p = jnp.concatenate([col_idx, jnp.full((md,), -1, jnp.int32)])
    if not use_pallas:
        return ref.lineage_gather_ref(queries, row_ptr, col_p, max_deg=md)
    md_pad = -(-md // 128) * 128 if md > 8 else md  # lane-align when big
    col_p = jnp.concatenate([col_idx, jnp.full((md_pad,), -1, jnp.int32)])
    q_p = _pad_to(queries, 0, block_q)
    out = lineage_gather_pallas(
        q_p, row_ptr, col_p, max_deg=md_pad, block_q=block_q, interpret=interpret
    )
    return out[:q, :md]


def bitset_rank(
    words,
    positions,
    *,
    block_q: int = 128,
    interpret: bool | None = None,
    use_pallas: bool = True,
):
    """Batched inclusive rank over one packed bitset."""
    words = jnp.asarray(words, dtype=jnp.uint32)
    positions = jnp.asarray(positions, dtype=jnp.int32)
    if interpret is None:
        interpret = not on_tpu()
    if not use_pallas:
        return ref.bitset_rank_ref(words, positions)
    q = positions.shape[0]
    # -1 pads resolve to rank 0 in-kernel via the pos<0 guard.
    p_p = _pad_to(positions, 0, block_q, value=0)
    out = bitset_rank_pallas(words, p_p, block_q=block_q, interpret=interpret)
    return out[:q]
