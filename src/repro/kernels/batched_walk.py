"""Fused K-hop batched-walk Pallas kernel (ROADMAP item 4).

A batched Q1/Q2 record probe over a linear op chain used to cost K×3 kernel
launches: per hop one select-OR bitplane contraction (``bitmatmul``), one
``bitset_rank`` for the per-probe frontier sizes, and one ``lineage_gather``
to materialize the frontier — with the mask stack bouncing through HBM (and
host memory, off-TPU) between every launch.  This kernel fuses the whole
chain into ONE launch:

* the probe mask lives in a VMEM scratch tile (``cur``) for the entire walk
  — it is read from HBM once and written once, never in between;
* the K relation bitplanes are zero-padded to one common square dim and
  stacked into a single ``(K, N, N/32)`` operand whose ``(1, bk, Nw)``
  blocks stream through the grid's innermost dimension — Pallas
  double-buffers the next plane block behind the current contraction;
* each hop's select-OR + contraction accumulates into a second scratch tile
  (``nxt``); at the hop's last contraction block the per-probe popcount
  (the fused ``bitset_rank``) is recorded and the frontier swaps into
  ``cur`` for the next hop.

Zero padding is inert under the (OR, AND) semiring — a padded row/column
can never set a bit — so one common padded dim is exact.  Index
materialization (the gather role) is a host-side ``flatnonzero`` over the
returned packed frontier, identical for the fused and unfused paths.

Grid ``(B/bb, K, N/bk)``: batch blocks are independent ("parallel"); hops
and contraction blocks carry the scratch accumulator ("arbitrary").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both installs.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["batched_walk_kernel", "batched_walk_pallas"]


def batched_walk_kernel(mask_ref, planes_ref, out_ref, counts_ref,
                        cur_ref, nxt_ref, *, block_k: int):
    """One (bb,) batch block × one hop × one bk-slice of the contraction."""
    hop = pl.program_id(1)
    ks = pl.program_id(2)
    n_hops = pl.num_programs(1)
    nks = pl.num_programs(2)

    @pl.when((hop == 0) & (ks == 0))
    def _load_mask():
        # one HBM read per batch block; the mask then stays VMEM-resident
        cur_ref[...] = mask_ref[...]

    @pl.when(ks == 0)
    def _clear_frontier():
        nxt_ref[...] = jnp.zeros_like(nxt_ref)

    kw = block_k // 32
    a_words = cur_ref[:, pl.dslice(ks * kw, kw)]  # (bb, bk/32) uint32
    bb = a_words.shape[0]
    # Unpack this slice of the resident mask: (bb, bk/32, 32) -> (bb, bk).
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, 32), 2)
    bits = (a_words[:, :, None] >> shifts) & jnp.uint32(1)
    bits = bits.reshape(bb, block_k)
    # 0 -> 0x00000000, 1 -> 0xFFFFFFFF lane masks (the select-OR).
    sel = jnp.uint32(0) - bits  # (bb, bk)

    b_words = planes_ref[0]  # (bk, Nw) uint32 — streamed, double-buffered
    tmp = sel[:, :, None] & b_words[None, :, :]  # (bb, bk, Nw)
    partial = jax.lax.reduce(tmp, jnp.uint32(0), jax.lax.bitwise_or, (1,))
    nxt_ref[...] = nxt_ref[...] | partial

    @pl.when(ks == nks - 1)
    def _hop_done():
        frontier = nxt_ref[...]
        # fused bitset_rank: per-probe frontier size for this hop
        pops = jax.lax.population_count(frontier).astype(jnp.int32)
        counts_ref[0, :] = jnp.sum(pops, axis=1)
        # the frontier becomes the next hop's resident mask
        cur_ref[...] = frontier

        @pl.when(hop == n_hops - 1)
        def _final():
            out_ref[...] = frontier


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_k", "interpret")
)
def batched_walk_pallas(
    mask_bits: jax.Array,
    planes: jax.Array,
    *,
    block_b: int = 8,
    block_k: int = 256,
    interpret: bool = False,
) -> tuple:
    """Fused walk over pre-padded operands.

    ``mask_bits`` is (B, N/32); ``planes`` is (K, N, N/32) — every hop
    padded to the one square dim N.  B % block_b == 0, N % block_k == 0.
    Returns ``(out_bits (B, N/32) uint32, counts (K, B) int32)``.
    ``repro.kernels.ops.batched_walk`` handles padding/stacking/unpadding.
    """
    b, nw = mask_bits.shape
    k, n, nw2 = planes.shape
    assert nw == nw2 and nw * 32 == n, (nw, nw2, n)
    assert b % block_b == 0 and n % block_k == 0

    grid = (b // block_b, k, n // block_k)
    return pl.pallas_call(
        functools.partial(batched_walk_kernel, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, nw), lambda i, j, ks: (i, 0)),
            pl.BlockSpec((1, block_k, nw), lambda i, j, ks: (j, ks, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, nw), lambda i, j, ks: (i, 0)),
            pl.BlockSpec((1, block_b), lambda i, j, ks: (j, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nw), jnp.uint32),
            jax.ShapeDtypeStruct((k, b), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_b, nw), jnp.uint32),  # cur: resident mask
            pltpu.VMEM((block_b, nw), jnp.uint32),  # nxt: hop accumulator
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")
        ),
        interpret=interpret,
    )(mask_bits, planes)
