"""Boolean-semiring bitplane matmul Pallas kernel.

The paper composes provenance tensors along a pipeline with Einstein
summation (Section IV).  Over binary relations the semiring is (OR, AND):

    C[i, j] = OR_m  A[i, m] AND B[m, j]

TPU adaptation (DESIGN.md §2): there is no MXU instruction for the boolean
semiring, so we bit-pack both operands into uint32 lanes — 32 boolean MACs
per VPU word op — and tile exactly like a dense GEMM so HBM->VMEM traffic
matches a matmul of 1/32 the bytes:

* ``a_bits``:  (M, K/32)  uint32 — relation A packed along the contraction dim
* ``b_bits``:  (K, N/32)  uint32 — relation B packed along the output dim
* ``c_bits``:  (M, N/32)  uint32 — result packed along the output dim

Grid (M/bm, Nw/bnw, K/bk); the K grid dimension accumulates into the same
output block (revisited block, init at k==0) — the canonical Pallas matmul
reduction pattern.  Inside a block each of the ``bk`` contraction steps is a
masked OR of a B row-segment into the accumulator, vectorized over lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both installs.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["bitmatmul_kernel", "bitmatmul_pallas"]


def bitmatmul_kernel(a_ref, b_ref, c_ref, *, block_k: int):
    """One (bm, bnw) output tile for one bk-slice of the contraction."""
    k_step = pl.program_id(2)

    a_words = a_ref[...]  # (bm, bk//32) uint32
    bm = a_words.shape[0]
    # Unpack the contraction bits: (bm, bk//32, 32) -> (bm, bk).
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, 32), 2)
    bits = (a_words[:, :, None] >> shifts) & jnp.uint32(1)
    bits = bits.reshape(bm, block_k)
    # 0 -> 0x00000000, 1 -> 0xFFFFFFFF lane masks.
    mask = jnp.uint32(0) - bits  # (bm, bk)

    b_words = b_ref[...]  # (bk, bnw) uint32
    # OR_k (mask[:, k, None] & b[k, :]) — an OR-reduction over the bk axis.
    tmp = mask[:, :, None] & b_words[None, :, :]  # (bm, bk, bnw)
    partial = jax.lax.reduce(tmp, jnp.uint32(0), jax.lax.bitwise_or, (1,))

    @pl.when(k_step == 0)
    def _init():
        c_ref[...] = partial

    @pl.when(k_step > 0)
    def _accum():
        c_ref[...] = c_ref[...] | partial


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_nw", "block_k", "interpret")
)
def bitmatmul_pallas(
    a_bits: jax.Array,
    b_bits: jax.Array,
    *,
    block_m: int = 8,
    block_nw: int = 128,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """C_bits = (OR,AND)-matmul of packed boolean relations.

    Shapes must be pre-padded: M % block_m == 0, (K/32) % (block_k/32) == 0,
    Nw % block_nw == 0.  ``repro.kernels.ops.bitmatmul`` handles padding.
    """
    m, kw = a_bits.shape
    k, nw = b_bits.shape
    assert kw * 32 == k, (kw, k)
    assert m % block_m == 0 and nw % block_nw == 0 and k % block_k == 0

    grid = (m // block_m, nw // block_nw, k // block_k)
    return pl.pallas_call(
        functools.partial(bitmatmul_kernel, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k // 32), lambda i, j, ks: (i, ks)),
            pl.BlockSpec((block_k, block_nw), lambda i, j, ks: (ks, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_nw), lambda i, j, ks: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, nw), jnp.uint32),
        compiler_params=_CompilerParams(dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a_bits, b_bits)
