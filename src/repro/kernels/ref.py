"""Pure-jnp oracles for every Pallas kernel in this package.

Each oracle is the simplest correct implementation; tests sweep shapes and
dtypes and assert exact equality (the kernels are integer/boolean — no
tolerance needed) against these under ``interpret=True``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "pack_bits",
    "unpack_bits",
    "bitmatmul_ref",
    "lineage_gather_ref",
    "bitset_rank_ref",
    "batched_walk_ref",
]


def pack_bits(dense: jax.Array) -> jax.Array:
    """bool (R, C) -> uint32 (R, ceil(C/32)), little-endian within a word."""
    r, c = dense.shape
    cw = (c + 31) // 32
    padded = jnp.zeros((r, cw * 32), dtype=jnp.uint32)
    padded = padded.at[:, :c].set(dense.astype(jnp.uint32))
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return (padded.reshape(r, cw, 32) << shifts[None, None, :]).sum(
        axis=-1, dtype=jnp.uint32
    )


def unpack_bits(words: jax.Array, n_cols: int) -> jax.Array:
    r, cw = words.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
    return bits.reshape(r, cw * 32)[:, :n_cols].astype(bool)


def bitmatmul_ref(a_bits: jax.Array, b_bits: jax.Array) -> jax.Array:
    """(OR,AND) matmul oracle: unpack, integer matmul, threshold, repack."""
    m, kw = a_bits.shape
    k, nw = b_bits.shape
    a = unpack_bits(a_bits, k).astype(jnp.int32)          # (m, k)
    b = unpack_bits(b_bits, nw * 32).astype(jnp.int32)    # (k, n)
    c = (a @ b) > 0                                       # boolean semiring
    return pack_bits(c)


def lineage_gather_ref(
    queries: jax.Array, row_ptr: jax.Array, col_idx: jax.Array, *, max_deg: int
) -> jax.Array:
    """Padded (Q, max_deg) neighbor table oracle (col_idx sentinel-padded)."""
    starts = row_ptr[queries]
    ends = row_ptr[queries + 1]
    lane = jnp.arange(max_deg, dtype=jnp.int32)[None, :]
    gather_idx = starts[:, None] + lane
    seg = col_idx[gather_idx]
    return jnp.where(lane < (ends - starts)[:, None], seg, jnp.int32(-1))


def batched_walk_ref(mask_bits: jax.Array, planes) -> tuple:
    """K-hop fused-walk oracle: fold :func:`bitmatmul_ref` over the chain.

    ``mask_bits`` (B, ⌈n_0/32⌉) packs B probe sets; ``planes[j]`` is the
    packed (n_j, ⌈n_{j+1}/32⌉) relation of hop j.  Returns the final packed
    frontier (B, ⌈n_K/32⌉) and the per-hop frontier sizes (K, B) int32 —
    the rank term of the per-hop rank/gather the fused kernel subsumes.
    """
    cur = mask_bits
    counts = []
    for plane in planes:
        cur = bitmatmul_ref(cur, plane)
        counts.append(
            jax.lax.population_count(cur).astype(jnp.int32).sum(axis=1)
        )
    return cur, jnp.stack(counts, axis=0)


def bitset_rank_ref(words: jax.Array, positions: jax.Array) -> jax.Array:
    """Inclusive rank oracle: rank(p) = popcount(bits[0..p]); rank(-1) = 0."""
    pops = jax.lax.population_count(words).astype(jnp.int32)
    prefix = jnp.cumsum(pops)
    w = positions // 32
    b = positions % 32
    word = words[jnp.maximum(w, 0)]
    mask = (jnp.uint32(0xFFFFFFFF) >> (31 - b.astype(jnp.uint32))).astype(jnp.uint32)
    partial = jax.lax.population_count(word & mask).astype(jnp.int32)
    before = jnp.where(w > 0, prefix[jnp.maximum(w - 1, 0)], 0)
    return jnp.where(positions < 0, 0, before + partial)
