"""Batched CSR lineage-probe Pallas kernel.

The paper's optimized tensor representation answers a lineage probe with
"three list accesses" (root -> dataset -> record -> triples).  The array
realization is a bidirectional CSR; a probe for query row ``q`` is:

    start, end = row_ptr[q], row_ptr[q+1]       (access 1, 2)
    neighbors  = col_idx[start:end]             (access 3 — bounded gather)

This kernel vectorizes the probe over a BATCH of queries — strictly more
general than the paper's scalar traversal — emitting a padded (Q, max_deg)
neighbor table (-1 padding).  ``col_idx`` must be padded by ``max_deg``
trailing sentinels so the dynamic contiguous slice never reads OOB.

TPU notes: each query issues one dynamic-slice of length ``max_deg`` from
VMEM (lane-aligned when max_deg % 128 == 0), so the inner loop is a vector
load + compare + select — no scatter, no ragged addressing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both installs.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["lineage_gather_kernel", "lineage_gather_pallas"]


def lineage_gather_kernel(queries_ref, row_ptr_ref, col_idx_ref, out_ref, *, block_q: int, max_deg: int):
    """Probe ``block_q`` queries against the full CSR resident in VMEM."""

    def body(qi, _):
        q = queries_ref[qi]
        start = pl.load(row_ptr_ref, (pl.dslice(q, 1),))[0]
        end = pl.load(row_ptr_ref, (pl.dslice(q + 1, 1),))[0]
        seg = pl.load(col_idx_ref, (pl.dslice(start, max_deg),))  # (max_deg,)
        lane = jax.lax.broadcasted_iota(jnp.int32, (max_deg,), 0)
        padded = jnp.where(lane < (end - start), seg, jnp.int32(-1))
        pl.store(out_ref, (pl.dslice(qi, 1), pl.dslice(0, max_deg)), padded[None, :])
        return 0

    jax.lax.fori_loop(0, block_q, body, 0)


@functools.partial(jax.jit, static_argnames=("max_deg", "block_q", "interpret"))
def lineage_gather_pallas(
    queries: jax.Array,   # (Q,) int32, Q % block_q == 0
    row_ptr: jax.Array,   # (R+1,) int32
    col_idx: jax.Array,   # (NNZ + max_deg,) int32 — sentinel-padded
    *,
    max_deg: int,
    block_q: int = 128,
    interpret: bool = False,
) -> jax.Array:
    (q,) = queries.shape
    assert q % block_q == 0, (q, block_q)
    grid = (q // block_q,)
    return pl.pallas_call(
        functools.partial(lineage_gather_kernel, block_q=block_q, max_deg=max_deg),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q,), lambda i: (i,)),
            pl.BlockSpec(row_ptr.shape, lambda i: (0,)),   # full CSR in VMEM
            pl.BlockSpec(col_idx.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_q, max_deg), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((q, max_deg), jnp.int32),
        compiler_params=_CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(queries, row_ptr, col_idx)
