"""Batched bitset rank Pallas kernel.

The paper's attribute maps (Section IV) are rank/select queries over the
Table-VI bitsets: ``map_vr_f(b, i) = sum_{k<=i} b_k`` etc.  This kernel
evaluates ``rank(pos) = popcount(bits[0..pos])`` (inclusive) for a batch of
positions against one packed bitset.

Structure: a word-level inclusive popcount prefix is computed once per block
(cumsum of ``lax.population_count`` over the words, VPU-friendly), then each
query resolves with two scalar reads: prefix[word-1] + popcount(word & mask).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both installs.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["bitset_rank_kernel", "bitset_rank_pallas"]


def bitset_rank_kernel(words_ref, pos_ref, out_ref, *, block_q: int):
    words = words_ref[...]  # (W,) uint32
    pops = jax.lax.population_count(words).astype(jnp.int32)
    prefix = jnp.cumsum(pops)  # inclusive per-word prefix

    def body(qi, _):
        pos = pos_ref[qi]
        safe = jnp.maximum(pos, 0)  # pos<0 = null query -> rank 0 (guarded below)
        w = safe // 32
        b = safe % 32
        word = pl.load(words_ref, (pl.dslice(w, 1),))[0]
        # bits [0..b] of the word
        mask = jnp.uint32(0xFFFFFFFF) >> (jnp.uint32(31) - b.astype(jnp.uint32))
        partial = jax.lax.population_count(word & mask).astype(jnp.int32)
        # prefix is a traced array (not a ref): gather with dynamic_slice
        before = jnp.where(
            w > 0,
            jax.lax.dynamic_slice_in_dim(prefix, jnp.maximum(w - 1, 0), 1)[0],
            0,
        )
        rank = jnp.where(pos < 0, 0, before + partial)
        pl.store(out_ref, (pl.dslice(qi, 1),), rank[None])
        return 0

    jax.lax.fori_loop(0, block_q, body, 0)


@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def bitset_rank_pallas(
    words: jax.Array,      # (W,) uint32
    positions: jax.Array,  # (Q,) int32, Q % block_q == 0
    *,
    block_q: int = 128,
    interpret: bool = False,
) -> jax.Array:
    (q,) = positions.shape
    assert q % block_q == 0, (q, block_q)
    grid = (q // block_q,)
    return pl.pallas_call(
        functools.partial(bitset_rank_kernel, block_q=block_q),
        grid=grid,
        in_specs=[
            pl.BlockSpec(words.shape, lambda i: (0,)),  # full bitset in VMEM
            pl.BlockSpec((block_q,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_q,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((q,), jnp.int32),
        compiler_params=_CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(words, positions)
